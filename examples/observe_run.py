"""Observability demo: flight-recorder tracing of a chaos training run.

    PYTHONPATH=src python examples/observe_run.py

Walks the repro.obs subsystem end to end:
  1. ``obs.setup`` builds one ObsContext (tracer + bounded flight-recorder
     ring + metrics registry) for the run;
  2. a TrainingCoordinator survives an injected host_crash / nan_poison /
     ckpt_corrupt sequence while every recovery path emits its
     ``fault.<kind>`` / ``recover.<kind>`` witness spans;
  3. each fault triggers a dump of the recorder window: ``.jsonl`` (the
     loadable form) plus Chrome ``trace_event`` JSON — open the
     ``*.trace.json`` files in chrome://tracing or Perfetto;
  4. the profiling hook wraps the jitted train step (compile vs.
     steady-state wall time, XLA cost_analysis FLOPs);
  5. the dumps are schema-validated and the registry exported as
     Prometheus text + JSON.
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import obs  # noqa: E402
from repro.chaos import (CKPT_CORRUPT, HOST_CRASH, NAN_POISON,  # noqa: E402
                         ChaosEngine, FaultEvent, FaultTrace)
from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticTokenPipeline  # noqa: E402
from repro.distributed.steps import make_train_step  # noqa: E402
from repro.ft import (CheckpointStore, DynamicInterval,  # noqa: E402
                      TrainingCoordinator)
from repro.models import lm  # noqa: E402
from repro.obs.validate import validate_dir  # noqa: E402
from repro.optim import adamw_init  # noqa: E402


def main() -> None:
    trace_dir = tempfile.mkdtemp(prefix="obs_trace_")
    ctx = obs.setup(trace_dir, dump_on_fault=True)

    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    step = obs.profile_jit(jax.jit(make_train_step(cfg, q_chunk=32,
                                                   xent_chunk=32)),
                           name="train_step", registry=ctx.registry,
                           tracer=ctx.tracer)

    chaos = ChaosEngine(FaultTrace(events=[
        FaultEvent(step=5, kind=NAN_POISON),
        FaultEvent(step=9, kind=CKPT_CORRUPT, targets=(0,)),
        FaultEvent(step=12, kind=HOST_CRASH, targets=(0,), duration=2),
    ]), tracer=ctx.tracer)

    print(f"== traced chaos run (dumps -> {trace_dir}) ==")
    coord = TrainingCoordinator(
        train_step=step, params=params, opt_state=adamw_init(params),
        pipeline=SyntheticTokenPipeline(DataConfig(global_batch=4,
                                                   seq_len=64), cfg),
        store=CheckpointStore(tempfile.mkdtemp(prefix="obs_ckpt_"),
                              tracer=ctx.tracer),
        interval=DynamicInterval(gamma_s=1.0, lam_min=3.0, lam_max=3.0),
        chaos=chaos, tracer=ctx.tracer, registry=ctx.registry)
    rep = coord.run(20)
    print(f"steps={rep.steps_completed} failures={rep.failures} "
          f"nan_rollbacks={rep.nan_rollbacks} "
          f"ckpt_fallbacks={rep.ckpt_fallbacks}")

    prof = step.report()
    print(f"compile={prof['compile_s']:.2f}s "
          f"mean_step={(prof['mean_s'] or 0) * 1e3:.1f}ms "
          f"steady_calls={prof['calls']}")

    ctx.finish()
    print(f"\n== flight-recorder dumps ({len(ctx.recorder.dumps)}) ==")
    for path in ctx.recorder.dumps:
        print(f"  {path}")
    print(f"faults seen:     {dict(ctx.recorder.faults_seen)}")
    print(f"recoveries seen: {dict(ctx.recorder.recoveries_seen)}")

    problems, summary = validate_dir(trace_dir, require_spans=[
        f"fault.{HOST_CRASH}", f"recover.{HOST_CRASH}", "ckpt.restore"])
    assert not problems, problems
    print(f"\nschema OK: {summary['jsonl_files']} dumps, "
          f"{summary['events']} records, "
          f"{len(summary['span_names'])} span names")

    print("\n== metrics (Prometheus exposition, excerpt) ==")
    for line in ctx.registry.to_prometheus().splitlines():
        if line.startswith(("train_", "profile_compile")):
            print(f"  {line}")


if __name__ == "__main__":
    main()
