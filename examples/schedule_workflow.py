"""CRCH workflow scheduling end-to-end (the paper's core use case).

    PYTHONPATH=src python examples/schedule_workflow.py [--workflow montage]
        [--size 100] [--env normal]

Generates a scientific workflow, learns replication counts with PCA +
triplet-loss clustering, schedules with over-provisioned HEFT, simulates
execution under the chosen failure environment, and compares against
plain HEFT and ReplicateAll(3).
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (CRCHConfig, CloudEnvironment, aggregate, baselines,  # noqa: E402
                        generate_workflow, metrics_from_result, plan,
                        sample_failure_trace, sim_config, simulate)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workflow", default="montage",
                    choices=("montage", "cybershake", "ligo", "sipht"))
    ap.add_argument("--size", type=int, default=100)
    ap.add_argument("--env", default="normal",
                    choices=("stable", "normal", "unstable"))
    ap.add_argument("--runs", type=int, default=10)
    args = ap.parse_args()

    wf = generate_workflow(args.workflow, args.size, seed=1)
    env = CloudEnvironment(wf, n_vms=20, seed=2)
    print(f"workflow: {wf.name} ({wf.n_tasks} tasks, "
          f"{len(wf.deps)} dependencies) on 20 VMs, env={args.env}")

    cfg = CRCHConfig()
    p = plan(wf, env, cfg, environment=args.env)
    hist = np.bincount(p.rep_counts)
    print(f"\nPCA: {p.pca.components.shape[0]} components "
          f"(COV={p.pca.cov:.2f})")
    print(f"supercluster sizes: {sorted(p.clustering.cluster_sizes, reverse=True)}")
    print("replication counts: "
          + ", ".join(f"{n} tasks x{c}" for c, n in enumerate(hist) if n))
    print(f"dynamic checkpoint interval lambda* = {p.ckpt_lambda:.0f}s "
          f"(Lemma 3.1, env={args.env})")
    print(f"HEFT makespan (no failures): {p.schedule.makespan:.0f}s; "
          f"critical path: {len(p.schedule.critical_path())} tasks")

    algos = {
        "CRCH": (p.schedule, sim_config(p, cfg)),
        "HEFT": (baselines.heft_plan(wf, env), baselines.heft_sim_config()),
        "ReplicateAll(3)": (baselines.replicate_all_plan(wf, env, 3),
                            baselines.replicate_all_sim_config()),
    }
    print(f"\n{'algo':16s} {'ok':>5s} {'TET':>8s} {'usage/TET':>10s} "
          f"{'waste/TET':>10s} {'SLR':>6s} {'resub':>6s}")
    for name, (sched, scfg) in algos.items():
        runs = []
        for i in range(args.runs):
            tr = sample_failure_trace(args.env, 20,
                                      horizon_s=40 * sched.makespan,
                                      seed=100 + i)
            runs.append(metrics_from_result(sched, simulate(sched, tr, scfg)))
        a = aggregate(runs)
        print(f"{name:16s} {a['success_rate']:5.2f} {a['tet']:8.0f} "
              f"{a['usage_frac']:10.2f} {a['wastage_frac']:10.3f} "
              f"{a['slr']:6.2f} {a['resubmissions']:6.1f}")


if __name__ == "__main__":
    main()
