"""Fault-tolerant training demo: failures, checkpoint/restart, dynamic
intervals, straggler replication, and compressed cross-pod gradients.

    PYTHONPATH=src python examples/fault_tolerant_train.py

Shows the paper's machinery as framework features:
  1. light-weight pointer checkpointing + atomic index commits;
  2. Weibull failure injection -> restore -> bit-exact replay;
  3. the Lemma-3.1-style dynamic checkpoint interval tightening as the
     observed MTBF shrinks;
  4. CRCH clustering of host telemetry assigning replication counts to
     data shards (straggler mitigation);
  5. int8 + error-feedback cross-pod gradient exchange (4x DCN bytes).
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticTokenPipeline  # noqa: E402
from repro.distributed.steps import make_train_step  # noqa: E402
from repro.ft import (CheckpointStore, DynamicInterval, FaultInjector,  # noqa: E402
                      HostTelemetry, PodGradientExchange,
                      ReplicationPlanner, TrainingCoordinator)
from repro.models import lm  # noqa: E402
from repro.optim import adamw_init  # noqa: E402


def main() -> None:
    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, q_chunk=32, xent_chunk=32))
    data_cfg = DataConfig(global_batch=4, seq_len=64, seed=0)

    # ---- 1-3: coordinator under injected failures --------------------------
    print("== coordinated training with injected failures ==")
    inj = FaultInjector(mtbf_steps=6.0, seed=3, horizon_steps=30)
    coord = TrainingCoordinator(
        train_step=step, params=params, opt_state=opt,
        pipeline=SyntheticTokenPipeline(data_cfg, cfg),
        store=CheckpointStore(tempfile.mkdtemp(prefix="ft_ckpt_")),
        interval=DynamicInterval(gamma_s=2.0, lam_min=2.0, lam_max=10.0),
        injector=inj)
    rep = coord.run(30)
    print(f"steps={rep.steps_completed} failures={rep.failures} "
          f"restores={rep.restores} wasted_steps={rep.wasted_steps} "
          f"checkpoints={rep.checkpoints}")
    print(f"dynamic lambda after observing failures: "
          f"{coord.interval.current_lambda():.1f}s "
          f"(MTBF estimate {coord.interval.mtbf():.1f}s)")

    # ---- 4: straggler replication via CRCH clustering -----------------------
    print("\n== CRCH replication heuristics on host telemetry ==")
    rng = np.random.default_rng(0)
    hosts = [HostTelemetry(host=h,
                           mean_step_s=1.0 + 0.03 * rng.standard_normal(),
                           p95_step_s=1.15, net_mbps=100.0)
             for h in range(14)]
    hosts += [HostTelemetry(host=14, mean_step_s=3.2, p95_step_s=6.1,
                            failure_count=5, net_mbps=25.0),
              HostTelemetry(host=15, mean_step_s=2.9, p95_step_s=5.0,
                            restarts=2, thermal_throttle_s=200.0)]
    plan = ReplicationPlanner(max_rep=3).plan(hosts)
    print(f"replication counts: {plan.counts.tolist()}")
    for shard in (14, 15):
        print(f"  shard {shard} (straggler) -> executed on hosts "
              f"{plan.assignments[shard]}")

    # ---- 5: compressed cross-pod gradients ----------------------------------
    print("\n== int8 + error-feedback cross-pod gradient exchange ==")
    g = {"w": np.asarray(rng.standard_normal((256, 256)), np.float32)}
    ex = PodGradientExchange(n_pods=2)
    acc = np.zeros_like(g["w"])
    for i in range(20):
        acc += np.asarray(ex.exchange([g, g])["w"])
    err = np.abs(acc / 20 - g["w"]).max()
    print(f"DCN compression {ex.compression_ratio:.1f}x; accumulated-update "
          f"max error after 20 steps: {err:.2e}")


if __name__ == "__main__":
    main()
