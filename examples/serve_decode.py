"""Batched serving demo: prefill + greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py [--arch olmo-1b]
        [--batch 4] [--prompt-len 32] [--new-tokens 16]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.distributed.steps import make_prefill_step, make_serve_step  # noqa: E402
from repro.launch.shapes import make_batch  # noqa: E402
from repro.models import lm  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    cache_len = args.prompt_len + args.new_tokens + (cfg.n_image_tokens or 0)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, batch=args.batch, seq=args.prompt_len)
    prompts = {k: v for k, v in batch.items()
               if k in ("tokens", "frames", "image_embeds")}

    prefill = jax.jit(make_prefill_step(cfg, cache_len, q_chunk=32))
    serve = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    pos0 = args.prompt_len + (cfg.n_image_tokens or 0)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        tok, logits, cache = serve(params, cache, tok, jnp.int32(pos0 + i))
        out_tokens.append(np.asarray(tok))
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"prefill: {t_prefill * 1e3:.0f} ms; decode: "
          f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/token "
          f"(CPU, tiny config)")
    for b in range(min(args.batch, 2)):
        print(f"  seq {b}: generated token ids {gen[b].tolist()}")
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
