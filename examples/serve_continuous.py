"""Fault-tolerant continuous-batching demo (repro.serve).

Submits a handful of requests with mixed prompt/decode lengths to the
slot-based engine, kills a worker mid-decode, and shows the affected
requests resuming from their latest decode snapshot with byte-identical
output (greedy decoding is deterministic).

    PYTHONPATH=src python examples/serve_continuous.py [--arch olmo-1b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import (EngineConfig, Request, ServeEngine,  # noqa: E402
                         WorkerPool, crch_policy, prompt_bucket)


def make_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        newt = 8 if i % 3 else 24
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab_size, plen,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=newt, arrival=0, deadline=10_000))
    return reqs


def run(cfg, params, reqs, *, fail_at=None):
    cache_len = max(prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    pool = WorkerPool(2, 2, mtbf_steps=None, mttr_steps=6, seed=0)
    engine = ServeEngine(cfg, EngineConfig(cache_len=cache_len, q_chunk=32,
                                           snapshot_lambda=4),
                         pool=pool, policy=crch_policy(reqs), params=params)
    for r in reqs:
        engine.submit(r)
    while engine.pending() and engine.step_no < 5_000:
        if fail_at is not None and engine.step_no == fail_at:
            pool.force_failure(engine.step_no, wid=0)
            print(f"  [step {engine.step_no}] worker 0 killed "
                  f"(back after {pool.mttr_steps} steps)")
        engine.step()
    return engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    reqs = make_requests(cfg, args.requests)

    print("clean run (no failures):")
    clean = run(cfg, params, reqs)
    print(f"  {len(clean.completed)}/{len(reqs)} completed in "
          f"{clean.step_no} steps")

    print("faulty run (worker 0 dies mid-decode):")
    faulty = run(cfg, params, reqs, fail_at=12)
    s = faulty.metrics.summary(faulty.step_no)
    print(f"  {len(faulty.completed)}/{len(reqs)} completed in "
          f"{faulty.step_no} steps | resubmissions "
          f"{int(s['resubmissions'])}, snapshot restores "
          f"{int(s['restores'])}")

    for rid in sorted(clean.completed):
        assert clean.completed[rid] == faulty.completed[rid], rid
    print("tokens after failure + snapshot resume are byte-identical "
          "to the failure-free run")
    print("sample:", clean.completed[0][:10])


if __name__ == "__main__":
    main()
