"""Quickstart: train a small LM end-to-end with the full stack.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--small]

Uses the olmo-family architecture at a reduced width (~11M params by
default; pass --full-100m for the ~100M variant if you have the patience on
CPU), the deterministic data pipeline, AdamW + cosine schedule, and
light-weight pointer checkpointing.
"""
import argparse
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticTokenPipeline  # noqa: E402
from repro.distributed.steps import make_train_step  # noqa: E402
from repro.ft import CheckpointStore  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    base = get_config("olmo-1b")
    if args.full_100m:
        cfg = dataclasses.replace(base, name="olmo-100m", n_layers=8,
                                  d_model=768, n_heads=12, n_kv_heads=12,
                                  d_ff=3072, vocab_size=32768)
    else:
        cfg = dataclasses.replace(base, name="olmo-11m", n_layers=4,
                                  d_model=256, n_heads=8, n_kv_heads=8,
                                  d_ff=1024, vocab_size=8192)
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    params = lm.init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3), q_chunk=min(512, args.seq),
        xent_chunk=128, warmup=20, total_steps=args.steps))
    pipeline = SyntheticTokenPipeline(
        DataConfig(args.batch, args.seq, seed=0), cfg)
    store = CheckpointStore(tempfile.mkdtemp(prefix="quickstart_ckpt_"))

    losses = []
    t0 = time.time()
    for i in range(args.steps):
        params, opt_state, m = step(params, opt_state, next(pipeline))
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"{(time.time() - t0) / (i + 1):.2f}s/step")
        if i > 0 and i % 100 == 0:
            store.save(i, {"params": params, "opt": opt_state},
                       extra=pipeline.state(), sync=False)
    store.wait()
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'FAILED to improve'}); "
          f"checkpoint at {store.root}")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
