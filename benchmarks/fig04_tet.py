"""Fig. 4 — Total Execution Time: CRCH vs HEFT (stable/normal), Montage.

The paper plots TET across workflow sizes for the stable and normal
environments (HEFT cannot execute unstable runs).
"""
from __future__ import annotations

from . import _harness as H


def run(fast: bool = True):
    sizes = (100, 300) if fast else (100, 200, 300, 400, 500, 600, 700)
    n_runs = 5 if fast else 10
    rows = []
    for size in sizes:
        wf, env = H.make_setup("montage", size)
        for envname in ("stable", "normal"):
            for algo in ("crch", "heft"):
                a = H.run_algo(algo, wf, env, envname, n_runs)
                rows.append({
                    "figure": "fig04", "workflow": "montage", "size": size,
                    "env": envname, "algo": algo, "tet": a["tet"],
                    "success_rate": a["success_rate"],
                    "resubmissions": a["resubmissions"],
                })
    return H.emit("fig04_tet", rows)


if __name__ == "__main__":
    H.print_csv("fig04_tet", run(True))
