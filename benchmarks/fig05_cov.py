"""Fig. 5 — Clustering overhead vs PCA coverage-of-variance threshold.

The paper finds COV 0.3-0.4 optimal: using every (noisy, co-dependent)
feature degrades the cluster assignments, too few loses information.
"""
from __future__ import annotations

from repro.core import CRCHConfig

from . import _harness as H


def run(fast: bool = True):
    covs = (0.2, 0.35, 0.6, 0.9) if fast else (0.1, 0.2, 0.3, 0.35, 0.4,
                                               0.5, 0.6, 0.7, 0.8, 0.9)
    n_runs = 5 if fast else 10
    wf, env = H.make_setup("montage", 100 if fast else 300)
    rows = []
    for envname in ("normal", "unstable") if fast else H.ENVS:
        for cov in covs:
            cfg = CRCHConfig(cov_threshold=cov)
            a = H.run_algo("crch", wf, env, envname, n_runs, crch_cfg=cfg)
            rows.append({
                "figure": "fig05", "env": envname, "cov_threshold": cov,
                "tet": a["tet"], "usage_frac": a["usage_frac"],
                "rep_hist": "|".join(map(str, a["rep_hist"])),
            })
    return H.emit("fig05_cov", rows)


if __name__ == "__main__":
    H.print_csv("fig05_cov", run(True))
