"""Run every paper-figure benchmark and print a combined CSV.

Usage:
  PYTHONPATH=src python -m benchmarks.run            # fast grid
  PYTHONPATH=src python -m benchmarks.run --full     # paper-size grid
  PYTHONPATH=src python -m benchmarks.run --only fig08 fig09
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import time

from . import _harness as H

FIGS = [
    "fig04_tet", "fig05_cov", "fig06_maxrep", "fig07_checkpoint",
    "fig08_usage", "fig09_wastage", "fig10_slr",
    "fig11_usage_types", "fig12_wastage_types",
    "tab_ri_comparison",
    "serve_slo",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size grid (sizes up to 700, 10 runs/DAX)")
    ap.add_argument("--quick", action="store_true",
                    help="single-row smoke variant for benchmarks that "
                         "support it (serve_slo)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="figure-name prefixes to run")
    args = ap.parse_args()

    for name in FIGS:
        if args.only and not any(name.startswith(o) for o in args.only):
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = {"fast": not args.full}
        if args.quick and "quick" in inspect.signature(mod.run).parameters:
            kwargs["quick"] = True
        t0 = time.perf_counter()
        rows = mod.run(**kwargs)
        wall = time.perf_counter() - t0
        H.print_csv(name, rows)
        print(f"# {name}: {len(rows)} rows in {wall:.1f}s\n")


if __name__ == "__main__":
    main()
