"""Chaos matrix: fault-class x recovery-path survival/goodput grid.

One row per (layer, fault class) cell of the ``repro.chaos`` taxonomy: each
cell replays a single-kind fault trace against the layer that owns the
recovery path —

* **serve** rows drive the continuous-batching engine (tiny config) and
  report completions, goodput, and the degraded-mode counters (shed /
  hedge-drops / snapshot-verify failures / past-first-token drops);
* **train** rows drive the fault-tolerant training coordinator and report
  steps survived, restores, checkpoint fallbacks, and NaN rollbacks.

A cell *survives* when every request is accounted for (completed or
deliberately shed, never dropped past its first token) respectively when
training reaches the target step with only finite losses.  Cells whose
sampled trace would be empty get one forced event so every recovery path is
exercised; ``ckpt_corrupt`` / ``snapshot_corrupt`` / ``disk_full`` events
are paired with a follow-up ``host_crash`` so the corrupted (resp. pruned)
state is actually *read* (the fallback is the interesting part, not the
flip).  The ``train/net_partition`` cell runs a 3-pod
``repro.ft.PodTrainingCluster`` against a fault-free reference and demands
the healed pods land bit-identical to it at equal step count with zero
split-brain fingerprint divergences.

Record/replay: ``--record DIR`` writes each cell's trace as JSON;
``--replay DIR`` re-runs from those files with **no RNG at all** — two
replays of the same directory produce byte-identical ``--out`` grids.
``--trace-dir DIR`` additionally runs every cell under the ``repro.obs``
flight recorder (dump-on-fault); tracing consumes no RNG and touches no
counters, so a traced replay's grid stays byte-identical to an untraced
one.  ``--only layer/kind[,layer/kind...]`` restricts the grid to the
named cells.

    PYTHONPATH=src python benchmarks/chaos_matrix.py --record /tmp/tr \
        --out /tmp/grid_a.json
    PYTHONPATH=src python benchmarks/chaos_matrix.py --replay /tmp/tr \
        --out /tmp/grid_b.json   # byte-identical to a third replay run
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import obs  # noqa: E402
from repro.chaos import (CHAOS_PROFILES, CKPT_CORRUPT,  # noqa: E402
                         DISK_FULL, HOST_CRASH, NET_PARTITION, SERVE_KINDS,
                         SNAPSHOT_CORRUPT, TRAIN_KINDS, ChaosEngine,
                         FaultEvent, FaultTrace, sample_trace)
from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticTokenPipeline  # noqa: E402
from repro.distributed.steps import make_train_step  # noqa: E402
from repro.ft import (CheckpointStore, DynamicInterval,  # noqa: E402
                      PodTrainingCluster, TrainingCoordinator, tree_digest)
from repro.models import lm  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from repro.serve import (EngineConfig, Request, ServeEngine,  # noqa: E402
                         WorkerPool, format_table, prompt_bucket,
                         uniform_policy)

# corruption cells pair each flip with a same-step host_crash so the
# corrupted state is read immediately (fault application always precedes
# failure processing within a step): the restore MUST take the fallback /
# re-prefill path before a fresh checkpoint or snapshot can paper over it
CRASH_LAG = 0


def cell_trace(profile: str, layer: str, kind: str, *, horizon: int,
               n_targets: int, seed: int) -> FaultTrace:
    """Single-kind trace for one matrix cell, guaranteed non-empty."""
    spec = CHAOS_PROFILES[profile]
    mttr = int(spec["mttr_steps"])
    trace = sample_trace(profile, horizon=horizon, n_targets=n_targets,
                         seed=seed, kinds=(kind,))
    if not trace.events:
        trace.events.append(FaultEvent(
            step=horizon // 3, kind=kind, targets=(0,), duration=mttr,
            seed=seed * 7919 + 1))
        trace.meta["forced"] = True
    # disk_full joins the paired-crash set: the follow-up restore must read
    # the committed index *after* the prune-and-retry rewrote it
    if kind in (CKPT_CORRUPT, SNAPSHOT_CORRUPT, DISK_FULL):
        crashes = [FaultEvent(step=ev.step + CRASH_LAG, kind=HOST_CRASH,
                              targets=tuple(range(n_targets)),
                              duration=mttr, seed=ev.seed + 1)
                   for ev in trace.events]
        trace.events = sorted(trace.events + crashes,
                              key=lambda e: (e.step, e.kind, e.targets))
        trace.meta["paired_crash_lag"] = CRASH_LAG
    trace.meta["layer"] = layer
    trace.meta["cell"] = kind
    return trace


def serve_workload(cfg, n: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = int(rng.integers(6, 24))
        newt = 24 if rid % 4 == 0 else 8
        arrival = int(rng.integers(0, 40))
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, plen,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=newt, arrival=arrival,
            deadline=arrival + 10 * (plen + newt)))
    return reqs


def run_serve_cell(cfg, params, trace: FaultTrace, *, n_requests: int,
                   max_steps: int, seed: int, tracer=None) -> dict:
    reqs = serve_workload(cfg, n_requests, seed + 17)
    cache_len = max(prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    pool = WorkerPool(4, 2, seed=seed)   # chaos supplies every fault
    engine = ServeEngine(
        cfg, EngineConfig(cache_len=cache_len, q_chunk=64,
                          snapshot_lambda=4),
        pool=pool, policy=uniform_policy(2), params=params,
        chaos=ChaosEngine(trace, tracer=tracer), tracer=tracer)
    for r in reqs:
        engine.submit(r)
    m = engine.run(max_steps=max_steps)
    s = m.summary(engine.step_no)
    accounted = int(s["completed"]) + int(s["shed"])
    survived = (accounted == n_requests and s["past_first_drops"] == 0)
    return {
        "layer": "serve", "fault": trace.meta["cell"],
        "events": float(len(trace)), "survived": float(survived),
        "completed": s["completed"], "in_deadline": s["in_deadline"],
        "goodput": s["goodput"], "restores": s["restores"],
        "resubmissions": s["resubmissions"], "shed": s["shed"],
        "hedge_drops": s["hedge_drops"],
        "snap_fail": s["snapshot_restore_failures"],
        "past_first": s["past_first_drops"], "steps": float(engine.step_no),
    }


def run_train_cell(cfg, trace: FaultTrace, *, n_steps: int,
                   seed: int, tracer=None) -> dict:
    params = lm.init_params(jax.random.key(seed), cfg)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                      q_chunk=64, xent_chunk=512,
                                      total_steps=n_steps))
    pipeline = SyntheticTokenPipeline(DataConfig(4, 64, seed=seed), cfg)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        coord = TrainingCoordinator(
            train_step=step_fn, params=params,
            opt_state=adamw_init(params), pipeline=pipeline,
            store=CheckpointStore(ckpt_dir, tracer=tracer),
            # tight cadence (~every 3 steps): the ckpt_corrupt cell needs a
            # predecessor checkpoint for the fallback restore to land on
            interval=DynamicInterval(gamma_s=0.5, lam_min=2.0,
                                     prior_mtbf_s=10.0),
            chaos=ChaosEngine(trace, tracer=tracer), tracer=tracer)
        rep = coord.run(n_steps)
    survived = (rep.steps_completed == n_steps
                and bool(np.all(np.isfinite(rep.losses)))
                and rep.index_violations == 0)
    return {
        "layer": "train", "fault": trace.meta["cell"],
        "events": float(len(trace)), "survived": float(survived),
        "steps": float(rep.steps_completed),
        "restores": float(rep.restores),
        "ckpt_fallbacks": float(rep.ckpt_fallbacks),
        "ckpt_corruptions": float(rep.ckpt_corruptions),
        "nan_rollbacks": float(rep.nan_rollbacks),
        "slowdowns": float(rep.slowdowns),
        "backoff": float(rep.backoff_steps),
        "wasted": float(rep.wasted_steps),
        "disk_full": float(rep.disk_full_events),
        "enospc_retries": float(rep.enospc_retries),
        "index_viol": float(rep.index_violations),
    }


def run_partition_cell(cfg, trace: FaultTrace, *, n_steps: int,
                       seed: int, tracer=None) -> dict:
    """net_partition cell: a 3-pod :class:`PodTrainingCluster` rides the
    trace (quorum trains, minority parks, heal catches up from the quorum
    checkpoint) next to a fault-free reference cluster.  The cell survives
    only when the healed cluster's pods all land **bit-identical** to the
    reference params at equal applied-step count, with zero split-brain
    fingerprint divergences and a clean committed-index audit."""
    def build(chaos, ckpt_dir, trc=None):
        return PodTrainingCluster(
            cfg=cfg, params=lm.init_params(jax.random.key(seed), cfg),
            pipeline=SyntheticTokenPipeline(DataConfig(2, 32, seed=seed),
                                            cfg),
            store=CheckpointStore(ckpt_dir, tracer=trc), n_pods=3,
            ckpt_every=4, chaos=chaos, tracer=trc)

    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        cluster = build(ChaosEngine(trace, tracer=tracer), da, tracer)
        rep = cluster.run(n_steps)
        reference = build(None, db)
        ref = reference.run(n_steps)
        ref_digest = tree_digest(reference.params[0])
        bit_identical = all(tree_digest(cluster.params[p]) == ref_digest
                            for p in range(cluster.n_pods))
    survived = (rep.steps_completed == n_steps
                and ref.steps_completed == n_steps
                and rep.split_brain_divergences == 0
                and bit_identical
                and bool(np.all(np.isfinite(rep.losses)))
                and rep.index_violations == 0)
    return {
        "layer": "train", "fault": trace.meta["cell"],
        "events": float(len(trace)), "survived": float(survived),
        "steps": float(rep.steps_completed),
        "rounds": float(rep.rounds),
        "partitions": float(rep.partitions),
        "parked": float(rep.parked_pod_rounds),
        "heals": float(rep.heals),
        "catchups": float(rep.catchups),
        "fp_div": float(rep.split_brain_divergences),
        "bit_identical": float(bit_identical),
        "index_viol": float(rep.index_violations),
    }


def trace_path(d: str, layer: str, kind: str) -> str:
    return os.path.join(d, f"{layer}_{kind}.json")


def run_matrix(args) -> list[dict]:
    cfg = get_config(args.arch, tiny=True)
    serve_params = lm.init_params(jax.random.key(args.seed), cfg)
    ctx = obs.setup(getattr(args, "trace_dir", "") or None,
                    dump_on_fault=True)
    tracer = ctx.tracer if ctx.enabled else None
    rows = []
    all_cells = ([("serve", k) for k in SERVE_KINDS] +
                 [("train", k) for k in TRAIN_KINDS])
    # pair each cell with its position in the FULL grid before filtering:
    # --only must not shift the per-cell trace seeds
    cells = list(enumerate(all_cells))
    only = {c.strip() for c in getattr(args, "only", "").split(",")
            if c.strip()}
    if only:
        unknown = only - {f"{lay}/{k}" for lay, k in all_cells}
        if unknown:
            raise SystemExit(f"--only: unknown cells {sorted(unknown)}")
        cells = [(i, (lay, k)) for i, (lay, k) in cells
                 if f"{lay}/{k}" in only]
    for i, (layer, kind) in cells:
        horizon = args.serve_horizon if layer == "serve" else args.steps
        if args.replay:
            trace = FaultTrace.load(trace_path(args.replay, layer, kind))
        else:
            n_targets = (4 if layer == "serve"
                         else 3 if kind == NET_PARTITION else 1)
            trace = cell_trace(args.profile, layer, kind, horizon=horizon,
                               n_targets=n_targets,
                               seed=args.seed * 101 + i)
        if args.record:
            os.makedirs(args.record, exist_ok=True)
            trace.save(trace_path(args.record, layer, kind))
        if layer == "serve":
            rows.append(run_serve_cell(
                cfg, serve_params, trace, n_requests=args.requests,
                max_steps=args.max_steps, seed=args.seed, tracer=tracer))
        elif kind == NET_PARTITION:
            rows.append(run_partition_cell(cfg, trace, n_steps=args.steps,
                                           seed=args.seed, tracer=tracer))
        else:
            rows.append(run_train_cell(cfg, trace, n_steps=args.steps,
                                       seed=args.seed, tracer=tracer))
        print(f"[{rows[-1]['layer']}/{rows[-1]['fault']}] "
              f"survived={int(rows[-1]['survived'])} "
              f"events={int(rows[-1]['events'])}", file=sys.stderr)
    if ctx.finish() is not None:
        print(f"trace: {len(ctx.recorder.dumps)} dump(s) under "
              f"{args.trace_dir}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--profile", default="unstable",
                    choices=sorted(CHAOS_PROFILES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--steps", type=int, default=30,
                    help="training steps per train cell")
    ap.add_argument("--serve-horizon", type=int, default=200)
    ap.add_argument("--max-steps", type=int, default=2_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--record", default="",
                    help="write each cell's fault trace into this directory")
    ap.add_argument("--replay", default="",
                    help="replay traces from this directory (no RNG)")
    ap.add_argument("--out", default="",
                    help="write the grid as JSON (deterministic: replaying "
                         "the same traces reproduces it byte-identically)")
    ap.add_argument("--trace-dir", default="",
                    help="run cells under the repro.obs flight recorder "
                         "(dump-on-fault); does not perturb the grid")
    ap.add_argument("--only", default="",
                    help="comma-separated layer/kind cells to run "
                         "(e.g. serve/host_crash,train/net_partition)")
    args = ap.parse_args()

    rows = run_matrix(args)
    serve_cols = [("fault", "fault"), ("events", "events"),
                  ("survived", "ok"), ("completed", "done"),
                  ("in_deadline", "slo"), ("goodput", "goodput/1k"),
                  ("restores", "restore"), ("resubmissions", "resub"),
                  ("shed", "shed"), ("hedge_drops", "hedge-"),
                  ("snap_fail", "snapfail"), ("past_first", "pfdrop")]
    train_cols = [("fault", "fault"), ("events", "events"),
                  ("survived", "ok"), ("steps", "steps"),
                  ("restores", "restore"), ("ckpt_fallbacks", "fallback"),
                  ("ckpt_corruptions", "corrupt"),
                  ("nan_rollbacks", "nanroll"), ("slowdowns", "slow"),
                  ("backoff", "backoff"), ("wasted", "wasted"),
                  ("disk_full", "dskfull"), ("enospc_retries", "enospc"),
                  ("parked", "parked"), ("catchups", "catchup"),
                  ("fp_div", "fpdiv"), ("bit_identical", "bitid"),
                  ("index_viol", "idxviol")]
    print("== serve ==")
    print(format_table([r for r in rows if r["layer"] == "serve"],
                       serve_cols))
    print("\n== train ==")
    print(format_table([r for r in rows if r["layer"] == "train"],
                       train_cols))
    failed = [f"{r['layer']}/{r['fault']}" for r in rows
              if not r["survived"]]
    print(f"\nsurvival {len(rows) - len(failed)}/{len(rows)}"
          + (f" (FAILED: {', '.join(failed)})" if failed else ""))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
        print(f"grid -> {args.out}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
