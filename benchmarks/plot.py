"""Render the paper-figure benchmarks to PNGs.

    PYTHONPATH=src python -m benchmarks.plot        # reads benchmarks/out/*.json

Produces one PNG per reproduced figure under ``benchmarks/out/plots/``,
styled after the paper's bar/line charts (Figs. 4-12).
"""
from __future__ import annotations

import json
import os

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "out")
PLOTS = os.path.join(OUT, "plots")

ALGO_COLOR = {"crch": "#2b6cb0", "heft": "#c05621", "ra3": "#718096",
              "crch_ckpt": "#2b6cb0", "scr": "#718096", "ri": "#38a169"}
ALGO_LABEL = {"crch": "CRCH", "heft": "HEFT", "ra3": "ReplicateAll(3)",
              "crch_ckpt": "CRCH ckpt", "scr": "SCR", "ri": "RI [7]"}


def _load(name):
    path = os.path.join(OUT, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _grouped_bars(ax, rows, xkey, ykey, series_key="algo"):
    xs = sorted({r[xkey] for r in rows}, key=str)
    series = sorted({r[series_key] for r in rows})
    w = 0.8 / len(series)
    for i, s in enumerate(series):
        vals = []
        for x in xs:
            match = [r[ykey] for r in rows
                     if r[xkey] == x and r[series_key] == s]
            v = match[0] if match else float("nan")
            vals.append(v if v == v else 0.0)
        pos = [j + i * w for j in range(len(xs))]
        ax.bar(pos, vals, w, label=ALGO_LABEL.get(s, s),
               color=ALGO_COLOR.get(s, None))
    ax.set_xticks([j + 0.4 - w / 2 for j in range(len(xs))])
    ax.set_xticklabels([str(x) for x in xs])
    ax.legend(fontsize=8)


def fig04():
    rows = _load("fig04_tet")
    if not rows:
        return
    fig, axes = plt.subplots(1, 2, figsize=(9, 3.2), sharey=True)
    for ax, env in zip(axes, ("stable", "normal")):
        sub = [r for r in rows if r["env"] == env]
        _grouped_bars(ax, sub, "size", "tet")
        ax.set_title(f"{env} environment")
        ax.set_xlabel("workflow size")
    axes[0].set_ylabel("TET (s)")
    fig.suptitle("Fig 4 — Total Execution Time (Montage)")
    fig.tight_layout()
    fig.savefig(os.path.join(PLOTS, "fig04_tet.png"), dpi=120)


def _env_bars(name, ykey, title, ylabel):
    rows = _load(name)
    if not rows:
        return
    fig, ax = plt.subplots(figsize=(5.5, 3.2))
    _grouped_bars(ax, rows, "env", ykey)
    ax.set_title(title)
    ax.set_ylabel(ylabel)
    fig.tight_layout()
    fig.savefig(os.path.join(PLOTS, f"{name}.png"), dpi=120)


def fig05():
    rows = _load("fig05_cov")
    if not rows:
        return
    fig, ax = plt.subplots(figsize=(5.5, 3.2))
    for env in sorted({r["env"] for r in rows}):
        sub = sorted((r for r in rows if r["env"] == env),
                     key=lambda r: r["cov_threshold"])
        ax.plot([r["cov_threshold"] for r in sub], [r["tet"] for r in sub],
                marker="o", label=env)
    ax.set_xlabel("coverage-of-variance threshold")
    ax.set_ylabel("avg TET (s)")
    ax.set_title("Fig 5 — Clustering overhead vs COV")
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(PLOTS, "fig05_cov.png"), dpi=120)


def fig06():
    rows = _load("fig06_maxrep")
    if not rows:
        return
    fig, ax = plt.subplots(figsize=(5.5, 3.2))
    for env in sorted({r["env"] for r in rows}):
        sub = sorted((r for r in rows if r["env"] == env),
                     key=lambda r: r["max_rep_count"])
        ax.plot([r["max_rep_count"] for r in sub], [r["tet"] for r in sub],
                marker="s", label=env)
    ax.set_xlabel("max replication count (K superclusters)")
    ax.set_ylabel("avg TET (s)")
    ax.set_title("Fig 6 — TET vs max replication count")
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(PLOTS, "fig06_maxrep.png"), dpi=120)


def fig07():
    rows = _load("fig07_checkpoint")
    if not rows:
        return
    fig, (a, b) = plt.subplots(1, 2, figsize=(9, 3.2))
    _grouped_bars(a, [r for r in rows if r["figure"] == "fig07a"],
                  "env", "tet")
    a.set_title("7a — CRCH ckpt vs SCR (TET)")
    a.set_ylabel("TET (s)")
    sub = sorted((r for r in rows if r["figure"] == "fig07b"),
                 key=lambda r: r["lambda"])
    b.plot([r["lambda"] for r in sub], [r["tet"] for r in sub], marker="o",
           color="#2b6cb0")
    b.set_xscale("log")
    b.set_xlabel("checkpoint interval lambda (s)")
    b.set_title("7b — TET vs lambda (stable, no replicas)")
    fig.tight_layout()
    fig.savefig(os.path.join(PLOTS, "fig07_checkpoint.png"), dpi=120)


def fig11_12():
    for name, ykey, title in (
            ("fig11_usage_types", "usage_frac", "Fig 11 — usage by workflow"),
            ("fig12_wastage_types", "wastage_frac",
             "Fig 12 — wastage by workflow")):
        rows = _load(name)
        if not rows:
            continue
        envs = sorted({r["env"] for r in rows})
        fig, axes = plt.subplots(1, len(envs), figsize=(11, 3.2),
                                 sharey=True)
        for ax, env in zip(axes, envs):
            _grouped_bars(ax, [r for r in rows if r["env"] == env],
                          "workflow", ykey)
            ax.set_title(env)
            ax.tick_params(axis="x", rotation=30)
        axes[0].set_ylabel(ykey)
        fig.suptitle(title)
        fig.tight_layout()
        fig.savefig(os.path.join(PLOTS, f"{name}.png"), dpi=120)


def main() -> None:
    os.makedirs(PLOTS, exist_ok=True)
    fig04()
    fig05()
    fig06()
    fig07()
    _env_bars("fig08_usage", "usage_frac",
              "Fig 8 — Avg Resource Usage (frac TET)", "usage / TET")
    _env_bars("fig09_wastage", "wastage_frac",
              "Fig 9 — Avg Resource Wastage (frac TET)", "wastage / TET")
    _env_bars("fig10_slr", "slr", "Fig 10 — Avg SLR", "SLR")
    fig11_12()
    made = sorted(os.listdir(PLOTS))
    print(f"wrote {len(made)} plots to {PLOTS}: {made}")


if __name__ == "__main__":
    main()
