"""Serving SLO benchmark: no-replication vs Replicate-All vs CRCH routing.

The online analogue of the paper's Figs. 8-12: a mixed request workload is
replayed through the continuous-batching engine under the stable / normal /
unstable failure environments, once per replication policy:

* ``none``   — single copy per request, restart from scratch on failure
  (the paper's plain-resubmission baseline);
* ``all-k``  — every request runs k copies (paper Replicate-All), no
  snapshots (replication is its whole fault-tolerance budget);
* ``crch``   — per-class replication learned unsupervised by the CRCH
  pipeline over request features, plus decode snapshots (the full
  CheckpointHEFT runtime of Algorithm 3).

Reports goodput (in-deadline completions), p50/p99 latency, and token
usage/wastage.  The paper's headline trade-off should reproduce online:
CRCH wastes fewer tokens than Replicate-All while completing more requests
within deadline than no-replication.

Runs standalone or as part of the ``benchmarks.run`` sweep (full mode
covers every ``_harness.ENVS`` environment; ``--quick`` is a single
normal-env olmo-1b row for smoke/overhead checks):

    PYTHONPATH=src python benchmarks/serve_slo.py --tiny
    PYTHONPATH=src python benchmarks/serve_slo.py --quick
    PYTHONPATH=src python -m benchmarks.run --only serve_slo --quick
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

try:
    from . import _harness as H
except ImportError:  # standalone: python benchmarks/serve_slo.py
    import _harness as H

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import (EngineConfig, Request, ServeEngine,  # noqa: E402
                         WorkerPool, crch_policy, format_table,
                         prompt_bucket, uniform_policy)

POLICIES = ("none", "all", "crch")


def make_workload(cfg, *, n_short: int, n_medium: int, n_long: int,
                  arrival_spread: int, slack_factor: float,
                  seed: int) -> list[Request]:
    """Mostly-short traffic with a tail of long-decode requests — the
    failure-exposed outlier class CRCH should learn to hedge."""
    rng = np.random.default_rng(seed)
    vocab = cfg.vocab_size
    spec = ([(int(rng.integers(6, 16)), 8) for _ in range(n_short)] +
            [(int(rng.integers(16, 32)), 16) for _ in range(n_medium)] +
            [(int(rng.integers(24, 32)), 48) for _ in range(n_long)])
    rng.shuffle(spec)
    reqs = []
    for rid, (plen, newt) in enumerate(spec):
        arrival = int(rng.integers(0, arrival_spread))
        frames = (rng.normal(size=(cfg.n_frames, cfg.d_model))
                  .astype(np.float32) if cfg.is_encdec else None)
        embeds = (rng.normal(size=(cfg.n_image_tokens, cfg.d_model))
                  .astype(np.float32) if cfg.n_image_tokens else None)
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(1, vocab, plen, dtype=np.int64).astype(np.int32),
            max_new_tokens=newt, arrival=arrival,
            deadline=arrival + int(slack_factor * (plen + newt)),
            frames=frames, image_embeds=embeds))
    return reqs


def policy_for(name: str, workload: list[Request], max_rep: int):
    if name == "crch":
        return crch_policy(workload, max_rep=max_rep)
    if name == "all":
        return uniform_policy(max_rep)
    return uniform_policy(1)


def run_cell(cfg, params, workload, *, policy_name: str, env: str,
             n_workers: int, slots_per_worker: int, max_rep: int,
             max_steps: int, seed: int) -> dict:
    offset = cfg.n_image_tokens or 0
    cache_len = max(offset + prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in workload)
    if cfg.rglru and cfg.window:
        cache_len = max(cache_len, cfg.window)
    policy = policy_for(policy_name, workload, max_rep)
    pool = WorkerPool(n_workers, slots_per_worker, environment=env,
                      seed=seed)
    # Only CRCH pairs replication with checkpointing (Algorithm 3); the
    # baselines match the paper's plain-resubmission and Replicate-All.
    ecfg = EngineConfig(cache_len=cache_len, q_chunk=64,
                        snapshots_enabled=(policy_name == "crch"))
    engine = ServeEngine(cfg, ecfg, pool=pool, policy=policy, params=params)
    for r in workload:
        engine.submit(r)
    t0 = time.perf_counter()
    metrics = engine.run(max_steps=max_steps)
    wall = time.perf_counter() - t0
    row = {"arch": cfg.name, "policy": policy.name, "env": env,
           **metrics.summary(engine.step_no)}
    row["steps"] = float(engine.step_no)
    row["wall_s"] = wall
    return row


def run(fast: bool = True, *, envs=None, seed: int = 0,
        arch: str = "olmo-1b", quick: bool = False) -> list[dict]:
    if envs is None:
        # full mode sweeps every harness environment (paper Figs. 8-12);
        # fast keeps the two that exercise failures; quick is one row-set
        envs = (("normal",) if quick
                else ("normal", "unstable") if fast else H.ENVS)
    cfg = get_config(arch, tiny=fast)
    params = lm.init_params(jax.random.key(seed), cfg)
    if quick:
        workload_kw = dict(n_short=10, n_medium=4, n_long=2,
                           arrival_spread=60, slack_factor=4.0)
        pool_kw = dict(n_workers=3, slots_per_worker=2, max_rep=2,
                       max_steps=1_000)
    elif fast:
        workload_kw = dict(n_short=20, n_medium=8, n_long=4,
                           arrival_spread=120, slack_factor=4.0)
        pool_kw = dict(n_workers=4, slots_per_worker=2, max_rep=3,
                       max_steps=2_000)
    else:
        workload_kw = dict(n_short=120, n_medium=48, n_long=24,
                           arrival_spread=600, slack_factor=4.0)
        pool_kw = dict(n_workers=8, slots_per_worker=4, max_rep=3,
                       max_steps=10_000)
    workload = make_workload(cfg, seed=seed + 17, **workload_kw)
    rows = []
    for env in envs:
        for pol in POLICIES:
            rows.append(run_cell(cfg, params,
                                 [r for r in workload],  # fresh list
                                 policy_name=pol, env=env, seed=seed,
                                 **pool_kw))
    return H.emit("serve_slo", rows)


def check_tradeoff(rows: list[dict]) -> list[str]:
    """Paper acceptance, per (arch, env): CRCH wastes less than
    Replicate-All and completes (in deadline) at least as much as
    no-replication, strictly more in at least one environment per arch."""
    msgs = []
    by = {(r["arch"], r["env"], r["policy"]): r for r in rows}
    archs = sorted({r["arch"] for r in rows})
    envs = sorted({r["env"] for r in rows})
    for arch in archs:
        strict = False
        for env in envs:
            all_name = next(p for (a, e, p) in by
                            if a == arch and e == env and p.startswith("all"))
            none_ = by[(arch, env, "none")]
            all_ = by[(arch, env, all_name)]
            crch = by[(arch, env, "crch")]
            ok_waste = crch["wasted_tokens"] < all_["wasted_tokens"]
            ok_done = crch["in_deadline"] >= none_["in_deadline"]
            strict |= crch["in_deadline"] > none_["in_deadline"]
            msgs.append(f"[{arch}/{env}] crch wasted "
                        f"{crch['wasted_tokens']:.0f} "
                        f"< all {all_['wasted_tokens']:.0f}: "
                        f"{'OK' if ok_waste else 'FAIL'} | crch in-deadline "
                        f"{crch['in_deadline']:.0f} >= none "
                        f"{none_['in_deadline']:.0f}: "
                        f"{'OK' if ok_done else 'FAIL'}")
            if not (ok_waste and ok_done):
                msgs.append(f"[{arch}/{env}] TRADE-OFF VIOLATED")
        msgs.append(f"[{arch}] strictly more in-deadline completions than "
                    f"no-replication in >=1 env: "
                    f"{'OK' if strict else 'FAIL'}")
    return msgs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="single normal-env olmo-1b row (smoke / recorder "
                         "overhead checks)")
    ap.add_argument("--arch", nargs="+", default=["olmo-1b", "rwkv6-3b"],
                    help="architectures to sweep (one engine run per arch)")
    ap.add_argument("--envs", nargs="+",
                    default=["normal", "unstable"],
                    choices=["stable", "normal", "unstable"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    fast = not args.full
    if args.quick:
        rows = run(True, seed=args.seed, arch=args.arch[0], quick=True)
    else:
        rows = []
        for arch in args.arch:
            rows.extend(run(fast, envs=tuple(args.envs), seed=args.seed,
                            arch=arch))
    cols = [("arch", "arch"), ("env", "env"), ("policy", "policy"),
            ("n_requests", "reqs"), ("completed", "done"),
            ("in_deadline", "slo"), ("goodput", "goodput/1k"),
            ("p50_latency", "p50"), ("p99_latency", "p99"),
            ("usage_tokens", "usage"), ("wasted_tokens", "wasted"),
            ("wastage_frac", "waste%"), ("failures", "fails"),
            ("resubmissions", "resub"), ("restores", "restore"),
            ("steps", "steps"), ("wall_s", "wall_s")]
    print(format_table(rows, cols))
    if args.quick:
        return  # smoke row: too small for the paper acceptance check
    print()
    for m in check_tradeoff(rows):
        print(m)


if __name__ == "__main__":
    main()
