"""Table (paper Section 2): CRCH clustering vs the Resubmission-Impact
heuristic of Plankensteiner et al. [7].

The paper's claim: learning replication counts by clustering "is much
quicker and robust, as it doesn't involve exploring every possible solution
(HEFT schedules with varying sets of replicas)".  We measure both planners'
wall time and the quality (TET / usage / success) of the schedules they
induce under the normal environment.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (CRCHConfig, aggregate, heft_schedule,
                        metrics_from_result, plan, resubmission_impact_counts,
                        sample_failure_trace, sim_config, simulate)
from repro.core.runtime import CkptLevel, SimConfig

from . import _harness as H


def run(fast: bool = True):
    # CRCH's one clustering pass vs RI's n HEFT re-computations: the
    # asymptotic gap (paper: "much quicker") shows from ~300 tasks
    # (6.3x at 300, 16.6x at 500 on this machine)
    sizes = (100, 300) if fast else (100, 300, 500, 700)
    n_runs = 5 if fast else 10
    rows = []
    for size in sizes:
        wf, env = H.make_setup("montage", size)
        # --- CRCH planning -------------------------------------------------
        t0 = time.perf_counter()
        cfg = CRCHConfig()
        p = plan(wf, env, cfg, environment="normal")
        t_crch = time.perf_counter() - t0
        # --- RI planning ----------------------------------------------------
        t0 = time.perf_counter()
        ri_counts = resubmission_impact_counts(wf, env, max_rep=4)
        ri_sched = heft_schedule(wf, env, ri_counts)
        t_ri = time.perf_counter() - t0
        ri_cfg = SimConfig(
            ckpt_levels=(CkptLevel(p.ckpt_lambda, cfg.ckpt_gamma),),
            resubmit=True, skip_when_complete=True, busy_terminate=True)

        for name, sched, scfg, t_plan, counts in (
                ("crch", p.schedule, sim_config(p, cfg), t_crch,
                 p.rep_counts),
                ("ri", ri_sched, ri_cfg, t_ri, ri_counts)):
            runs = []
            for i in range(n_runs):
                tr = sample_failure_trace("normal", env.n_vms,
                                          horizon_s=40 * sched.makespan,
                                          seed=100 + i)
                runs.append(metrics_from_result(
                    sched, simulate(sched, tr, scfg)))
            a = aggregate(runs)
            rows.append({
                "table": "ri_comparison", "workflow": "montage",
                "size": size, "planner": name,
                "plan_wall_s": round(t_plan, 3),
                "mean_copies": float(np.mean(counts)),
                "tet": a["tet"], "usage_frac": a["usage_frac"],
                "success_rate": a["success_rate"],
            })
    return H.emit("tab_ri_comparison", rows)


if __name__ == "__main__":
    H.print_csv("tab_ri_comparison", run(True))
