"""Fig. 7 — Checkpoint overhead: CRCH light-weight vs SCR (a); TET vs lambda (b).

Both sides run with *no replicas* (paper setting): (a) compares the CRCH
single-level pointer checkpointing (dynamic lambda*) against SCR's two-level
local+PFS scheme per environment; (b) sweeps a fixed lambda in the stable
environment, exposing the convex TET(lambda) of Lemma 3.1.
"""
from __future__ import annotations

import numpy as np

from repro.core import (baselines, checkpoint_policy, sample_failure_trace,
                        simulate)
from repro.core.failures import ENVIRONMENTS
from repro.core.heft import heft_schedule

from . import _harness as H


def run(fast: bool = True):
    n_runs = 5 if fast else 10
    wf, env = H.make_setup("ligo", 100 if fast else 300)
    sched = heft_schedule(wf, env, 1)  # no replicas
    rows = []

    # ---- (a) CRCH checkpointing vs SCR across environments ---------------
    for envname in H.ENVS:
        lam_star = checkpoint_policy.optimal_lambda(
            sched, ENVIRONMENTS[envname], gamma=1.5)
        cfgs = {
            "crch_ckpt": baselines.crch_ckpt_only_sim_config(
                lam=lam_star, gamma=1.5),
            "scr": baselines.scr_sim_config(),
        }
        for name, cfg in cfgs.items():
            tets, overheads, wastes, ok = [], [], [], 0
            for i in range(n_runs):
                tr = sample_failure_trace(envname, env.n_vms,
                                          horizon_s=40 * sched.makespan,
                                          seed=100 + i)
                res = simulate(sched, tr, cfg)
                ok += res.completed
                overheads.append(res.ckpt_overhead)
                wastes.append(res.wastage)
                if res.completed:
                    tets.append(res.tet)
            rows.append({
                "figure": "fig07a", "env": envname, "algo": name,
                "lambda": lam_star if name == "crch_ckpt" else 30.0,
                "tet": float(np.mean(tets)) if tets else float("nan"),
                "ckpt_overhead": float(np.mean(overheads)),
                "wastage": float(np.mean(wastes)),
                "success_rate": ok / n_runs,
            })

    # ---- (b) TET sensitivity to a fixed lambda (stable env) --------------
    lam_grid = (5, 15, 40, 120, 400) if fast else (2, 5, 10, 20, 40, 80,
                                                   160, 320, 640)
    traces = [sample_failure_trace("stable", env.n_vms,
                                   horizon_s=40 * sched.makespan,
                                   seed=200 + i) for i in range(n_runs)]
    for lam, tet in checkpoint_policy.empirical_lambda_grid(
            sched, traces, lam_grid, gamma=1.5):
        rows.append({"figure": "fig07b", "env": "stable", "algo": "crch_ckpt",
                     "lambda": lam, "tet": tet, "ckpt_overhead": float("nan"),
                     "wastage": float("nan"), "success_rate": 1.0})
    return H.emit("fig07_checkpoint", rows)


if __name__ == "__main__":
    H.print_csv("fig07_checkpoint", run(True))
