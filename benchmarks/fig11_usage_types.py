"""Fig. 11 — Resource Usage across workflow types (CRCH and RA3).

The paper's trend: LIGO >> CyberShake > SIPHT/Montage in CPU intensity, so
usage rises accordingly; under RA3 the futile-replication usage flattens the
between-workflow differences relative to CRCH.
"""
from __future__ import annotations

from . import _harness as H


def run(fast: bool = True):
    n_runs = 4 if fast else 10
    rows = []
    for kind in ("montage", "cybershake", "ligo", "sipht"):
        wf, env = H.make_setup(kind, 100 if fast else 300)
        for envname in H.ENVS:
            for algo in ("crch", "ra3"):
                a = H.run_algo(algo, wf, env, envname, n_runs)
                rows.append({
                    "figure": "fig11", "workflow": kind, "env": envname,
                    "algo": algo, "usage_frac": a["usage_frac"],
                    "usage": a["usage"],
                })
    return H.emit("fig11_usage_types", rows)


if __name__ == "__main__":
    H.print_csv("fig11_usage_types", run(True))
