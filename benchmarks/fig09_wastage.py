"""Fig. 9 — Average Resource Wastage (fraction of TET): CRCH/HEFT/RA3.

HEFT wastage comes from failed runs (everything executed was futile);
CRCH wastage = beyond-last-checkpoint losses + late-replica executions;
RA3 wastage = replica seconds executed after the first success.
"""
from __future__ import annotations

from . import _harness as H


def run(fast: bool = True):
    n_runs = 5 if fast else 10
    wf, env = H.make_setup("montage", 100 if fast else 300)
    rows = []
    for envname in H.ENVS:
        for algo in ("crch", "heft", "ra3"):
            a = H.run_algo(algo, wf, env, envname, n_runs)
            rows.append({
                "figure": "fig09", "workflow": "montage", "env": envname,
                "algo": algo, "wastage_frac": a["wastage_frac"],
                "wastage": a["wastage"], "success_rate": a["success_rate"],
            })
    return H.emit("fig09_wastage", rows)


if __name__ == "__main__":
    H.print_csv("fig09_wastage", run(True))
