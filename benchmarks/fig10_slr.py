"""Fig. 10 — Average Standard Length Ratio (SLR): CRCH/HEFT/RA3."""
from __future__ import annotations

from . import _harness as H


def run(fast: bool = True):
    n_runs = 5 if fast else 10
    wf, env = H.make_setup("montage", 100 if fast else 300)
    rows = []
    for envname in H.ENVS:
        for algo in ("crch", "heft", "ra3"):
            a = H.run_algo(algo, wf, env, envname, n_runs)
            rows.append({
                "figure": "fig10", "workflow": "montage", "env": envname,
                "algo": algo, "slr": a["slr"],
                "success_rate": a["success_rate"],
            })
    return H.emit("fig10_slr", rows)


if __name__ == "__main__":
    H.print_csv("fig10_slr", run(True))
