"""Fig. 12 — Resource Wastage across workflow types (CRCH and RA3)."""
from __future__ import annotations

from . import _harness as H


def run(fast: bool = True):
    n_runs = 4 if fast else 10
    rows = []
    for kind in ("montage", "cybershake", "ligo", "sipht"):
        wf, env = H.make_setup(kind, 100 if fast else 300)
        for envname in H.ENVS:
            for algo in ("crch", "ra3"):
                a = H.run_algo(algo, wf, env, envname, n_runs)
                rows.append({
                    "figure": "fig12", "workflow": kind, "env": envname,
                    "algo": algo, "wastage_frac": a["wastage_frac"],
                    "wastage": a["wastage"],
                })
    return H.emit("fig12_wastage_types", rows)


if __name__ == "__main__":
    H.print_csv("fig12_wastage_types", run(True))
