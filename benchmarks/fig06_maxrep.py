"""Fig. 6 — Clustering overhead vs max replication count (supercluster K).

Higher K -> more superclusters -> higher replica budget -> TET grows; K=1
(no replicas) pays resubmission latency instead (paper Section 4.2).
"""
from __future__ import annotations

from repro.core import CRCHConfig

from . import _harness as H


def run(fast: bool = True):
    ks = (1, 2, 4, 6) if fast else (1, 2, 3, 4, 5, 6, 7, 8)
    n_runs = 5 if fast else 10
    wf, env = H.make_setup("montage", 100 if fast else 300)
    rows = []
    for envname in ("normal", "unstable") if fast else H.ENVS:
        for k in ks:
            cfg = CRCHConfig(max_rep_count=k)
            a = H.run_algo("crch", wf, env, envname, n_runs, crch_cfg=cfg)
            rows.append({
                "figure": "fig06", "env": envname, "max_rep_count": k,
                "tet": a["tet"], "usage_frac": a["usage_frac"],
                "resubmissions": a["resubmissions"],
            })
    return H.emit("fig06_maxrep", rows)


if __name__ == "__main__":
    H.print_csv("fig06_maxrep", run(True))
