"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) cell on the single-pod mesh:

  compute term    = FLOPs / (chips * peak)           [analytic FLOPs; XLA's
                    cost_analysis counts loop bodies once -- see
                    tests/test_analysis.py for the validation of the
                    analytic model against unrolled-HLO counts]
  memory term     = HBM bytes / (chips * hbm_bw)
  collective term = link bytes / (chips * link_bw)   [trip-count-scaled HLO
                    parse of all-gather/all-reduce/reduce-scatter/
                    all-to-all/collective-permute; ring factors applied]

Hardware (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

``--profile profile.json`` joins measured step wall times (written by the
``repro.obs`` profiling hooks under ``launch/train.py --trace-dir``)
against the analytic terms: achieved FLOP/s, fraction of single-chip
peak, and arithmetic intensity per profiled step fn.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--csv out]
    PYTHONPATH=src python -m benchmarks.roofline --profile out/profile.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.analysis import flops as F
from repro.analysis import hlo as H
from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)
CHIPS = {"single": 256, "multi": 512}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "out", "dryrun")


def analyze_cell(arch: str, shape_name: str, mesh: str) -> dict | None:
    jpath = os.path.join(DRYRUN_DIR, f"{arch}__{shape_name}__{mesh}.json")
    if not os.path.exists(jpath):
        return None
    row = json.load(open(jpath))
    out = {"arch": arch, "shape": shape_name, "mesh": mesh,
           "status": row["status"]}
    if row["status"] != "ok":
        out["reason"] = row.get("reason", row.get("error", ""))[:100]
        return out
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    chips = CHIPS[mesh]
    cost = F.cell_flops(cfg, shape)

    # collective bytes: trip-count-scaled HLO parse (per-device already)
    hpath = os.path.join(DRYRUN_DIR, f"{arch}__{shape_name}__{mesh}.hlo.gz")
    if os.path.exists(hpath):
        totals = H.collective_totals(H.load_hlo(hpath))
        link_bytes_dev = H.link_bytes(totals)
        out["collective_detail"] = {k: int(v)
                                    for k, v in totals["bytes"].items()}
        tot_b = sum(totals["bytes"].values())
        # fraction of collective bytes that are fp32: on this CPU backend a
        # chunk of these are bf16 dot operands force-upcast (a TPU would
        # move them in bf16) -- upper-bounds the inflation of the term
        out["f32_share"] = (sum(totals.get("bytes_f32", {}).values())
                            / tot_b if tot_b else 0.0)
    else:
        link_bytes_dev = 0.0
        out["f32_share"] = 0.0

    t_compute = cost.flops / (chips * PEAK_FLOPS)
    t_memory = cost.hbm_bytes / (chips * HBM_BW)
    t_coll = link_bytes_dev / LINK_BW          # per-device bytes already
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    out.update(
        flops=cost.flops,
        model_flops=cost.model_flops,
        useful_ratio=cost.model_flops / max(cost.flops, 1.0),
        hbm_bytes=cost.hbm_bytes,
        link_bytes_per_dev=link_bytes_dev,
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        dominant=dominant,
        # fraction of roofline: useful compute time / bound time
        roofline_fraction=(cost.model_flops / (chips * PEAK_FLOPS))
        / max(bound, 1e-12),
        temp_gib=row["memory"].get("temp_size_in_bytes", 0) / 2**30,
        args_gib=row["memory"].get("argument_size_in_bytes", 0) / 2**30,
    )
    return out


def profile_rows(path: str) -> list[dict]:
    """Join a ``repro.obs`` ``profile.json`` (measured wall times + XLA
    cost_analysis) against the machine peaks.  Measured on whatever host
    ran the profile, so ``peak_frac`` is indicative, not a TPU claim."""
    rows = []
    for p in json.load(open(path)):
        mean = p.get("mean_s")
        flops = p.get("flops")
        nbytes = p.get("bytes_accessed")
        rows.append({
            "section": "profile",
            "name": p["name"],
            "compile_s": p.get("compile_s"),
            "calls": p.get("calls", 0),
            "mean_s": mean,
            "flops": flops,
            "achieved_flops_per_s": (flops / mean if flops and mean
                                     else None),
            "peak_frac": (flops / mean / PEAK_FLOPS if flops and mean
                          else None),
            "intensity_flops_per_byte": (flops / nbytes
                                         if flops and nbytes else None),
        })
    return rows


def print_profile_section(rows: list[dict]) -> None:
    hdr = (f"{'step fn':16s} {'compile_s':>10s} {'calls':>6s} "
           f"{'mean_s':>10s} {'GFLOP/s':>9s} {'peak%':>7s} {'F/B':>7s}")
    print("\nmeasured profile (repro.obs):")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        def fmt(v, spec, scale=1.0):
            return f"{v * scale:{spec}}" if v is not None else "-"
        print(f"{r['name']:16s} {fmt(r['compile_s'], '10.3f')} "
              f"{r['calls']:6d} {fmt(r['mean_s'], '10.4g')} "
              f"{fmt(r['achieved_flops_per_s'], '9.3g', 1e-9)} "
              f"{fmt(r['peak_frac'], '7.4f', 100.0)} "
              f"{fmt(r['intensity_flops_per_byte'], '7.2f')}")


def main() -> None:
    global DRYRUN_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--dir", default=None,
                    help="alternate dry-run artifact dir (e.g. a baseline "
                         "snapshot for before/after comparisons)")
    ap.add_argument("--json-out",
                    default=os.path.join(os.path.dirname(__file__), "out",
                                         "roofline.json"))
    ap.add_argument("--profile", default="",
                    help="profile.json from launch/train.py --trace-dir; "
                         "appends a measured achieved-FLOP/s section")
    args = ap.parse_args()
    if args.dir:
        DRYRUN_DIR = args.dir

    rows = []
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>9s} {'useful':>7s} {'roofline':>9s} "
           f"{'mem GiB':>8s} {'f32%':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for arch in ARCHS:
        for shape_name in shp.SHAPES:
            r = analyze_cell(arch, shape_name, args.mesh)
            if r is None:
                continue
            rows.append(r)
            if r["status"] != "ok":
                print(f"{arch:24s} {shape_name:12s} "
                      f"[{r['status']}: {r.get('reason', '')[:60]}]")
                continue
            print(f"{arch:24s} {shape_name:12s} {r['t_compute_s']:10.4g} "
                  f"{r['t_memory_s']:10.4g} {r['t_collective_s']:10.4g} "
                  f"{r['dominant']:>9s} {r['useful_ratio']:7.2f} "
                  f"{r['roofline_fraction']:9.3f} "
                  f"{r['temp_gib'] + r['args_gib']:8.2f} "
                  f"{100 * r['f32_share']:5.0f}")
    if args.profile:
        prof = profile_rows(args.profile)
        print_profile_section(prof)
        rows.extend(prof)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"\nwrote {args.json_out}")


if __name__ == "__main__":
    main()
