"""Shared benchmark harness for the paper-figure reproductions.

Each ``figNN_*.py`` module exposes ``run(fast: bool) -> list[dict]`` returning
CSV-able rows; ``benchmarks.run`` executes all of them and tees a combined
CSV.  ``fast=True`` (default in CI) shrinks sizes/seeds; ``--full`` matches
the paper's grid (sizes 100-700, 10 runs per DAX).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (CRCHConfig, CloudEnvironment, aggregate, baselines,
                        generate_workflow, metrics_from_result, plan,
                        sample_failure_trace, sim_config, simulate)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

ENVS = ("stable", "normal", "unstable")


def make_setup(kind: str, size: int, *, seed: int = 0):
    wf = generate_workflow(kind, size, seed=seed)
    env = CloudEnvironment(wf, 20, seed=seed + 1)
    return wf, env


def run_algo(algo: str, wf, env, envname: str, n_runs: int, *,
             crch_cfg: CRCHConfig | None = None, seed0: int = 100):
    """Run one algorithm over ``n_runs`` failure traces; return aggregates."""
    crch_cfg = crch_cfg or CRCHConfig()
    if algo == "crch":
        p = plan(wf, env, crch_cfg, environment=envname)
        sched, cfg = p.schedule, sim_config(p, crch_cfg)
        extra = {"ckpt_lambda": p.ckpt_lambda,
                 "rep_hist": np.bincount(p.rep_counts).tolist()}
    elif algo == "heft":
        sched, cfg = baselines.heft_plan(wf, env), baselines.heft_sim_config()
        extra = {}
    elif algo == "ra3":
        sched = baselines.replicate_all_plan(wf, env, 3)
        cfg = baselines.replicate_all_sim_config()
        extra = {}
    else:
        raise ValueError(algo)
    horizon = 40.0 * sched.makespan
    runs = []
    t0 = time.perf_counter()
    for i in range(n_runs):
        tr = sample_failure_trace(envname, env.n_vms, horizon_s=horizon,
                                  seed=seed0 + i)
        res = simulate(sched, tr, cfg)
        runs.append(metrics_from_result(sched, res))
    agg = aggregate(runs)
    agg["wall_s"] = time.perf_counter() - t0
    agg.update(extra)
    return agg


def emit(name: str, rows: list[dict]) -> list[dict]:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return rows


def print_csv(name: str, rows: list[dict]) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"# {name}")
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r.get(k, '')}" if not isinstance(r.get(k), float)
                       else f"{r[k]:.4g}" for k in keys))
