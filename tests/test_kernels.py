"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.pairwise_affinity import ops as pa_ops, ref as pa_ref
from repro.kernels.rglru_scan import ops as lru_ops, ref as lru_ref
from repro.kernels.rwkv6_scan import ops as wk_ops, ref as wk_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# pairwise affinity (the paper's clustering hot spot)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,f", [(16, 4), (100, 10), (130, 3), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_pairwise_affinity(n, f, dtype):
    pts = jnp.asarray(RNG.normal(size=(n, f)), dtype)
    got = pa_ops.pairwise_distance(pts, interpret=True)
    want = pa_ref.pairwise_distance(pts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 2, 128, 128), (2, 8, 8, 256, 128), (1, 2, 1, 130, 128),
    (1, 4, 2, 384, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, h, kv, s, d, dtype, causal):
    if not causal and s % 128:
        pytest.skip("non-causal requires pre-padded inputs")
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,w", [(2, 128, 128), (3, 100, 96), (8, 256, 256),
                                   (1, 17, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(b, s, w, dtype):
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (b, s, w)), dtype)
    x = jnp.asarray(0.1 * RNG.normal(size=(b, s, w)), dtype)
    got = lru_ops.lru_scan(a, x, interpret=True)
    want, _ = lru_ref.lru_scan(a, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# WKV6 chunked scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,t,n", [(1, 2, 32, 64), (2, 3, 48, 64),
                                     (1, 1, 20, 64), (1, 2, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_scan(b, h, t, n, dtype):
    r = jnp.asarray(0.5 * RNG.normal(size=(b, h, t, n)), dtype)
    k = jnp.asarray(0.5 * RNG.normal(size=(b, h, t, n)), dtype)
    v = jnp.asarray(0.5 * RNG.normal(size=(b, h, t, n)), dtype)
    lw = jnp.asarray(-RNG.uniform(0.01, 2.5, (b, h, t, n)), jnp.float32)
    u = jnp.asarray(0.2 * RNG.normal(size=(h, n)), jnp.float32)
    got = wk_ops.wkv6(r, k, v, lw, u, interpret=True)
    want, _ = wk_ref.wkv6(r, k, v, lw, u)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=5e-2 if dtype == jnp.bfloat16 else 2e-4, rtol=5e-2)


def test_wkv6_kernel_matches_model_chunked_path():
    """The Pallas kernel and the model's jnp chunked path agree."""
    from repro.models import rwkv6 as rw
    b, h, t, n = 1, 2, 48, 64
    r = jnp.asarray(0.3 * RNG.normal(size=(b, h, t, n)), jnp.float32)
    k = jnp.asarray(0.3 * RNG.normal(size=(b, h, t, n)), jnp.float32)
    v = jnp.asarray(0.3 * RNG.normal(size=(b, h, t, n)), jnp.float32)
    lw = jnp.asarray(-RNG.uniform(0.01, 2.5, (b, h, t, n)), jnp.float32)
    u = jnp.asarray(0.1 * RNG.normal(size=(h, n)), jnp.float32)
    got = wk_ops.wkv6(r, k, v, lw, u, interpret=True)
    want, _ = wk_ref.wkv6(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=1e-3)
