"""Fault-tolerance substrate tests: checkpointing, restart determinism,
elastic restore, dynamic intervals, straggler replication, grad compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.distributed.steps import make_train_step
from repro.ft import (CheckpointStore, DynamicInterval, FaultInjector,
                      HostTelemetry, PodGradientExchange, ReplicationPlanner,
                      TrainingCoordinator)
from repro.models import lm
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("olmo_1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, q_chunk=16, xent_chunk=16))
    data = SyntheticTokenPipeline(DataConfig(global_batch=4, seq_len=32),
                                  cfg)
    return cfg, params, opt, step, data


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    cfg, params, opt, _, _ = tiny_setup
    store = CheckpointStore(str(tmp_path), n_hosts=4)
    tree = {"params": params, "opt": opt}
    store.save(7, tree, extra={"next_index": 3, "seed": 0})
    restored, step, extra = store.restore(tree)
    assert step == 7 and extra["next_index"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path, tiny_setup):
    cfg, params, *_ = tiny_setup
    store = CheckpointStore(str(tmp_path), n_hosts=2)
    store.save(1, {"params": params})
    # corrupt one shard file
    victim = None
    for root, _, files in os.walk(tmp_path):
        for f in files:
            if f.endswith(".npy"):
                victim = os.path.join(root, f)
                break
    arr = np.load(victim)
    np.save(victim, arr + 1.0)
    with pytest.raises(IOError, match="checksum"):
        store.restore({"params": params})


def test_async_checkpoint_commits(tmp_path, tiny_setup):
    cfg, params, *_ = tiny_setup
    store = CheckpointStore(str(tmp_path))
    store.save(3, {"params": params}, sync=False)
    store.wait()
    assert store.latest_step() == 3


# ---------------------------------------------------------------------------
# coordinator: failures / restore / determinism
# ---------------------------------------------------------------------------
def test_training_survives_failures_and_stays_deterministic(tmp_path,
                                                            tiny_setup):
    cfg, params, opt, step, data = tiny_setup
    # run A: no failures
    coordA = TrainingCoordinator(
        train_step=step, params=params, opt_state=opt,
        pipeline=SyntheticTokenPipeline(data.cfg, cfg),
        store=CheckpointStore(str(tmp_path / "a")),
        interval=DynamicInterval(gamma_s=1.0, lam_min=3.0, lam_max=3.0),
        injector=None)
    repA = coordA.run(8)
    # run B: failures at steps 3 and 6, recovery via checkpoint replay
    inj = FaultInjector(mtbf_steps=3.0, seed=1, horizon_steps=8)
    coordB = TrainingCoordinator(
        train_step=step, params=params, opt_state=opt,
        pipeline=SyntheticTokenPipeline(data.cfg, cfg),
        store=CheckpointStore(str(tmp_path / "b")),
        interval=DynamicInterval(gamma_s=1.0, lam_min=3.0, lam_max=3.0),
        injector=inj)
    repB = coordB.run(8)
    assert repB.failures > 0 and repB.restores == repB.failures
    assert repA.steps_completed == repB.steps_completed == 8
    # bit-identical final params: replayed steps consume identical batches
    for a, b in zip(jax.tree.leaves(coordA.params),
                    jax.tree.leaves(coordB.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dynamic_interval_tightens_under_instability():
    stable = DynamicInterval(gamma_s=2.0)
    unstable = DynamicInterval(gamma_s=2.0)
    for t in np.arange(0, 20_000, 5000):       # rare failures
        stable.record_failure(float(t))
    for t in np.arange(0, 2_000, 100):          # frequent failures
        unstable.record_failure(float(t))
    assert unstable.current_lambda() < stable.current_lambda()
    # Young/Daly: lambda* = sqrt(2 gamma MTBF)
    assert unstable.current_lambda() == pytest.approx(
        np.sqrt(2 * 2.0 * unstable.mtbf()), rel=0.01)


def test_elastic_restore_across_host_counts(tmp_path, tiny_setup):
    """The pointer index is host-count agnostic: save with 4 hosts,
    restore with 1 (elastic downscale) and continue training."""
    cfg, params, opt, step, data = tiny_setup
    store4 = CheckpointStore(str(tmp_path), n_hosts=4)
    store4.save(5, {"params": params, "opt": opt},
                extra={"next_index": 5, "seed": 0})
    store1 = CheckpointStore(str(tmp_path), n_hosts=1)
    tree, s, extra = store1.restore({"params": params, "opt": opt})
    assert s == 5
    batch = data.batch_at(extra["next_index"])
    p2, o2, m = step(tree["params"], tree["opt"], batch)
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# straggler replication planning (CRCH clustering on host telemetry)
# ---------------------------------------------------------------------------
def test_straggler_outliers_get_replicas():
    rng = np.random.default_rng(0)
    hosts = []
    for h in range(18):   # healthy pool
        hosts.append(HostTelemetry(
            host=h, mean_step_s=1.0 + 0.02 * rng.standard_normal(),
            p95_step_s=1.1 + 0.02 * rng.standard_normal(),
            net_mbps=100.0))
    hosts.append(HostTelemetry(host=18, mean_step_s=3.5, p95_step_s=6.0,
                               failure_count=4, restarts=2, net_mbps=20.0))
    hosts.append(HostTelemetry(host=19, mean_step_s=4.0, p95_step_s=7.0,
                               failure_count=6, restarts=3, net_mbps=15.0,
                               thermal_throttle_s=120.0))
    plan = ReplicationPlanner(max_rep=3).plan(hosts)
    healthy_counts = plan.counts[:18]
    straggler_counts = plan.counts[18:]
    assert healthy_counts.max() <= straggler_counts.min()
    assert straggler_counts.min() >= 2      # stragglers replicated
    for shard in (18, 19):
        execs = plan.assignments[shard]
        assert len(execs) >= 2
        assert any(h in plan.healthy_hosts for h in execs[1:])


def test_replica_shards_are_bit_identical_anywhere():
    """Deterministic pipeline -> speculative replicas need no reconciliation."""
    cfg = get_config("olmo_1b", tiny=True)
    pipe = SyntheticTokenPipeline(DataConfig(global_batch=8, seq_len=16), cfg)
    a = pipe.batch_at(12, host=3, n_hosts=4)
    b = pipe.batch_at(12, host=3, n_hosts=4)   # "another host" recomputes
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
# compressed cross-pod gradient exchange
# ---------------------------------------------------------------------------
def test_grad_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    true_grad = {"w": rng.standard_normal((64, 64)).astype(np.float32)}
    ex = PodGradientExchange(n_pods=2)
    acc_compressed = np.zeros((64, 64), np.float32)
    steps = 50
    for _ in range(steps):
        avg = ex.exchange([true_grad, true_grad])
        acc_compressed += np.asarray(avg["w"])
    # with error feedback the *accumulated* update converges to the truth
    err = np.abs(acc_compressed / steps - true_grad["w"]).max()
    assert err < 5e-3
    assert ex.compression_ratio == pytest.approx(4.0)


def test_grad_compression_roundtrip_bounds():
    from repro.optim import compress_int8, decompress_int8
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((128, 32)) * 0.1, jnp.float32)
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9
