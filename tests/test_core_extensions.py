"""Tests: MLP replication learner (paper Eqs. 3-4), RI baseline [7],
DAX parsing."""
import time

import numpy as np
import pytest

from repro.core import (CRCHConfig, CloudEnvironment, MLPConfig,
                        ReplicationMLP, generate_workflow, parse_dax, plan,
                        resubmission_impact_counts, task_features)


# ---------------------------------------------------------------------------
# supervised MLP distills the clustering policy (paper Section 3.1.1)
# ---------------------------------------------------------------------------
def test_mlp_learns_clustering_policy():
    wf = generate_workflow("montage", 300, seed=1)
    env = CloudEnvironment(wf, 20, seed=2)
    p = plan(wf, env, CRCHConfig())
    feats = task_features(wf, env)
    mlp = ReplicationMLP(MLPConfig(n_features=feats.shape[1],
                                   n_classes=int(p.rep_counts.max()),
                                   epochs=400, seed=0))
    loss = mlp.fit(feats, p.rep_counts)
    acc = mlp.accuracy(feats, p.rep_counts)
    assert np.isfinite(loss)
    assert acc > 0.85, f"train accuracy {acc}"
    # environment-insensitivity (paper conclusion: "corresponding tasks in
    # identical workflows end up having a similar number of replications,
    # irrespective of the environment"): same DAG, different VM pool
    env2 = CloudEnvironment(wf, 20, seed=11)
    feats2 = task_features(wf, env2)
    pred = mlp.predict(feats2)
    agree = float(np.mean(pred == p.rep_counts))
    assert agree > 0.6, f"cross-environment agreement {agree}"


# ---------------------------------------------------------------------------
# RI heuristic: high-impact (critical-path) tasks get more replicas, and the
# paper's speed claim (clustering beats per-task HEFT re-computation) holds
# ---------------------------------------------------------------------------
def test_resubmission_impact_counts_and_cost():
    wf = generate_workflow("montage", 100, seed=1)
    env = CloudEnvironment(wf, 20, seed=2)
    t0 = time.perf_counter()
    counts = resubmission_impact_counts(wf, env, max_rep=4)
    ri_time = time.perf_counter() - t0
    assert counts.shape == (wf.n_tasks,)
    assert counts.min() >= 1 and counts.max() <= 4
    assert counts.max() >= 2, "no task deemed impactful"
    t0 = time.perf_counter()
    p = plan(wf, env, CRCHConfig())
    crch_time = time.perf_counter() - t0
    # paper: the clustering approach "is much quicker" than RI
    assert crch_time < ri_time, (crch_time, ri_time)
    # critical-path tasks should be replicated at least as much as average
    cp = set(p.schedule.critical_path())
    cp_mean = np.mean([counts[t] for t in cp])
    assert cp_mean >= counts.mean() - 1e-9


# ---------------------------------------------------------------------------
# DAX parsing
# ---------------------------------------------------------------------------
DAX = """<?xml version="1.0" encoding="UTF-8"?>
<adag xmlns="http://pegasus.isi.edu/schema/DAX" version="2.1" count="1">
  <job id="ID0" name="mProjectPP" runtime="12.5">
    <uses file="in0.fits" link="input" size="2000000"/>
    <uses file="p0.fits" link="output" size="4000000"/>
  </job>
  <job id="ID1" name="mProjectPP" runtime="11.0">
    <uses file="in1.fits" link="input" size="2000000"/>
    <uses file="p1.fits" link="output" size="4000000"/>
  </job>
  <job id="ID2" name="mDiffFit" runtime="8.0">
    <uses file="p0.fits" link="input" size="4000000"/>
    <uses file="p1.fits" link="input" size="4000000"/>
    <uses file="d0.fits" link="output" size="1000000"/>
  </job>
  <child ref="ID2">
    <parent ref="ID0"/>
    <parent ref="ID1"/>
  </child>
</adag>
"""


def test_parse_dax_structure_and_volumes():
    wf = parse_dax(DAX)
    assert wf.n_tasks == 3
    assert wf.tasks[0].runtime == pytest.approx(12.5)
    parents = {p for p, _ in wf.parents[2]}
    assert parents == {0, 1}
    vol = dict(((c, p), d) for c, p, d in wf.deps)
    assert vol[(2, 0)] == pytest.approx(4.0)     # 4 MB from p0.fits
    wf.topo_order()                               # acyclic
    # schedulable end-to-end
    env = CloudEnvironment(wf, 4, seed=0)
    p = plan(wf, env, CRCHConfig(max_rep_count=2))
    assert p.schedule.makespan > 0
