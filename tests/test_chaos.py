"""Tests for repro.chaos: trace record/replay determinism plus every fault
class's dedicated recovery path across the training and serving layers.

Recovery-path coverage map (one test per taxonomy entry):

* ``host_crash``       -> test_serve_chaos_trace_replay_is_identical /
                          test_train_escalating_backoff_on_repeated_step
* ``slowdown``         -> test_serve_slowdown_stalls_then_resumes_bit_identical
                          / test_train_slowdown_and_capacity_loss
* ``capacity_loss``    -> test_serve_capacity_loss_sheds_hopeless_only
* ``ckpt_corrupt``     -> test_restore_falls_back_to_previous_checkpoint /
                          test_train_ckpt_corrupt_falls_back
* ``snapshot_corrupt`` -> test_serve_snapshot_corrupt_falls_back_to_reprefill
* ``nan_poison``       -> test_train_nan_poison_guard_skips_batch
* ``net_partition``    -> test_train_net_partition_parks_single_actor
                          (quorum/minority split: tests/test_crosspod.py)
* ``disk_full``        -> test_store_enospc_prunes_oldest_and_retries /
                          test_train_disk_full_prunes_and_survives
"""
import collections
import os

import jax
import numpy as np
import pytest

from repro.chaos import (CAPACITY_LOSS, CKPT_CORRUPT, DISK_FULL, HOST_CRASH,
                         NAN_POISON, NET_PARTITION, SERVE_KINDS, SLOWDOWN,
                         SNAPSHOT_CORRUPT, ChaosEngine, FaultEvent,
                         FaultTrace, corrupt_checkpoint_shard, sample_trace)
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.distributed.steps import make_train_step
from repro.ft import (CheckpointStore, DynamicInterval, FaultInjector,
                      TrainingCoordinator)
from repro.models import lm
from repro.optim import adamw_init
from repro.serve import (AdmissionQueue, EngineConfig, Request, ServeEngine,
                         WorkItem, WorkerPool, prompt_bucket, uniform_policy)


# ------------------------------------------------------------- fixtures ----

@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def train_setup():
    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, q_chunk=16, xent_chunk=16))
    data_cfg = DataConfig(global_batch=4, seq_len=32)
    return cfg, params, opt, step, data_cfg


def _req(rid, plen, newt, *, arrival=0, deadline=None, vocab=256, seed=0):
    rng = np.random.default_rng(seed * 7919 + rid)
    return Request(rid=rid,
                   prompt=rng.integers(1, vocab, plen,
                                       dtype=np.int64).astype(np.int32),
                   max_new_tokens=newt, arrival=arrival, deadline=deadline)


def _engine(cfg, params, reqs, *, workers=2, slots=2, chaos=None,
            policy=None, snapshot_lambda=4, max_steps=2_000):
    cache_len = max(prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    pool = WorkerPool(workers, slots, mtbf_steps=0.0, mttr_steps=6, seed=0)
    engine = ServeEngine(
        cfg, EngineConfig(cache_len=cache_len, q_chunk=32,
                          snapshot_lambda=snapshot_lambda),
        pool=pool, policy=policy or uniform_policy(1), params=params,
        chaos=chaos)
    for r in reqs:
        engine.submit(r)
    engine.run(max_steps=max_steps)
    return engine


def _coordinator(train_setup, tmp_path, *, chaos=None, injector=None,
                 lam=2.0, name="ckpt"):
    cfg, params, opt, step, data_cfg = train_setup
    return TrainingCoordinator(
        train_step=step, params=params, opt_state=opt,
        pipeline=SyntheticTokenPipeline(data_cfg, cfg),
        store=CheckpointStore(str(tmp_path / name)),
        interval=DynamicInterval(gamma_s=1.0, lam_min=lam, lam_max=lam),
        injector=injector, chaos=chaos)


# ---------------------------------------------------- traces and replay ----

def test_sample_trace_deterministic_and_roundtrips(tmp_path):
    a = sample_trace("unstable", horizon=300, n_targets=4, seed=11)
    b = sample_trace("unstable", horizon=300, n_targets=4, seed=11)
    assert a.to_json() == b.to_json() and len(a) > 0
    assert sample_trace("unstable", horizon=300, n_targets=4,
                        seed=12).to_json() != a.to_json()
    path = str(tmp_path / "trace.json")
    a.save(path)
    assert FaultTrace.load(path).to_json() == a.to_json()
    only = sample_trace("unstable", horizon=300, seed=11,
                        kinds=(HOST_CRASH,))
    assert only.kinds() == {HOST_CRASH}


def test_trace_load_rejects_unknown_version(tmp_path):
    trace = FaultTrace(events=[FaultEvent(step=1, kind=HOST_CRASH)])
    path = str(tmp_path / "trace.json")
    trace.save(path)
    import json
    with open(path) as f:
        d = json.load(f)
    d["version"] = 99
    with open(path, "w") as f:
        json.dump(d, f)
    with pytest.raises(ValueError, match="'version'"):
        FaultTrace.load(path)


def test_trace_rejects_unknown_fault_kind():
    trace = FaultTrace(events=[FaultEvent(step=1, kind=HOST_CRASH)])
    d = trace.to_json()
    d["events"][0]["kind"] = "gamma_ray"
    with pytest.raises(ValueError, match="gamma_ray"):
        FaultTrace.from_json(d)


def test_chaos_engine_fires_each_event_exactly_once():
    trace = FaultTrace(events=[
        FaultEvent(step=3, kind=HOST_CRASH, targets=(0,), duration=2),
        FaultEvent(step=3, kind=SLOWDOWN, targets=(1,), duration=4),
        FaultEvent(step=7, kind=NAN_POISON)])
    eng = ChaosEngine(trace)
    assert eng.pending() == 3
    assert len(eng.events_at(3)) == 2
    assert eng.events_at(3) == []          # never re-fires
    assert [e.kind for e in eng.events_at(7)] == [NAN_POISON]
    assert eng.pending() == 0
    assert eng.applied_by_kind == collections.Counter(
        {HOST_CRASH: 1, SLOWDOWN: 1, NAN_POISON: 1})


# ------------------------------------------- fault injector (multiset) ----

def test_fault_injector_multiset_defer_not_absorbed():
    inj = FaultInjector(mtbf_steps=10.0, seed=0, horizon_steps=0)
    inj.fail_steps = {5, 8}               # legacy set assignment still works
    assert 5 in inj.fail_steps and inj.fails_at(8)
    inj.defer(5, 8)                       # lands on an occupied step
    assert 5 not in inj.fail_steps
    assert inj.fail_steps[8] == 2         # stacked, not absorbed
    assert inj.consume(8) and inj.consume(8)
    assert not inj.consume(8)
    inj.fail_steps = collections.Counter({3: 2})   # mapping form
    assert inj.consume(3) and inj.consume(3) and not inj.consume(3)


# ---------------------------------------------------- checkpoint store ----

def test_restore_falls_back_to_previous_checkpoint(tmp_path):
    """Flipped bytes in a committed shard: restore must land on the previous
    verified checkpoint with the bad shard quarantined (reason logged)."""
    store = CheckpointStore(str(tmp_path), n_hosts=2)
    for s in (1, 2, 3):
        store.save(s, {"w": np.arange(1000.0) * s, "b": np.ones(600) * s},
                   extra={"next_index": s})
    assert corrupt_checkpoint_shard(store, seed=0) is not None
    like = {"w": np.zeros(1000), "b": np.zeros(600)}
    tree, step, extra = store.restore(like)
    assert step == 2 and extra["next_index"] == 2
    np.testing.assert_array_equal(tree["w"], np.arange(1000.0) * 2)
    assert store.last_restore_fallbacks == 1
    assert store.quarantined and \
        "checksum" in store.quarantined[0]["reason"]
    assert os.path.exists(os.path.join(str(tmp_path), "quarantine",
                                       "LOG.jsonl"))
    # the failed index is retired: the next restore goes straight to step 2
    assert store.committed_steps() == [1, 2]


def test_restore_raises_clear_error_when_all_corrupt(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"w": np.arange(64.0)})
    store.save(2, {"w": np.arange(64.0) + 9})
    for root, _, files in os.walk(tmp_path):
        for f in files:
            if f.endswith(".npy"):
                p = os.path.join(root, f)
                np.save(p, np.load(p) + 1.0)
    with pytest.raises(IOError, match="checksum"):
        store.restore({"w": np.zeros(64)})
    assert len(store.quarantined) == 2


class _Boom:
    def __array__(self, *a, **k):
        raise RuntimeError("boom: disk full")


def test_async_save_errors_surface_from_wait(tmp_path):
    """An exception inside the async _write thread must re-raise from
    wait(), never silently leave a stale pointer."""
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"x": np.ones(8)})
    store.save(2, {"x": _Boom()}, sync=False)
    with pytest.raises(RuntimeError, match="disk full"):
        store.wait()
    assert store.latest_step() == 1       # failed save committed nothing
    store.save(3, {"x": np.ones(8)}, sync=False)   # store remains usable
    store.wait()
    assert store.latest_step() == 3


def test_store_enospc_prunes_oldest_and_retries(tmp_path):
    """A mid-save ENOSPC must free space by pruning the *oldest* committed
    checkpoint and retry — the committed index stays consistent throughout
    and the new save lands."""
    store = CheckpointStore(str(tmp_path))
    for s in (1, 2, 3):
        store.save(s, {"w": np.arange(256.0) * s}, extra={"next_index": s})
    store.inject_disk_full()
    store.save(4, {"w": np.arange(256.0) * 4}, extra={"next_index": 4},
               sync=False)
    store.wait()
    assert store.enospc_retries == 1
    assert store.pruned_for_space == [1]      # oldest went first
    assert store.committed_steps() == [2, 3, 4]
    assert store.verify_committed() == []     # every index entry verifies
    tree, step, extra = store.restore({"w": np.zeros(256)})
    assert step == 4 and extra["next_index"] == 4
    np.testing.assert_array_equal(tree["w"], np.arange(256.0) * 4)


def test_store_enospc_with_nothing_to_prune_raises(tmp_path):
    """With no older committed checkpoint to free, the ENOSPC surfaces —
    and commits nothing (no torn index entry)."""
    store = CheckpointStore(str(tmp_path))
    store.inject_disk_full()
    with pytest.raises(OSError):
        store.save(1, {"w": np.ones(64)})
    assert store.committed_steps() == []
    assert store.verify_committed() == []


# ----------------------------------------------------- training chaos ----

def test_train_nan_poison_guard_skips_batch(tmp_path, train_setup):
    trace = FaultTrace(events=[FaultEvent(step=2, kind=NAN_POISON)])
    coord = _coordinator(train_setup, tmp_path, chaos=ChaosEngine(trace))
    rep = coord.run(6)
    assert rep.steps_completed == 6
    assert rep.nan_rollbacks == 1 and rep.skipped_batches == 1
    assert all(np.isfinite(rep.losses))
    assert coord._nan_skip                # poisoned batch stays quarantined


def test_train_ckpt_corrupt_falls_back(tmp_path, train_setup):
    """ckpt_corrupt + same-step crash: the restore must skip the corrupted
    newest checkpoint and recover from its predecessor."""
    trace = FaultTrace(events=[
        FaultEvent(step=4, kind=CKPT_CORRUPT, seed=7),
        FaultEvent(step=4, kind=HOST_CRASH, duration=2)])
    coord = _coordinator(train_setup, tmp_path, chaos=ChaosEngine(trace))
    rep = coord.run(8)
    assert rep.steps_completed == 8
    assert rep.ckpt_corruptions == 1
    assert rep.ckpt_fallbacks >= 1 and rep.restores >= 1
    assert coord.store.quarantined


def test_train_escalating_backoff_on_repeated_step(tmp_path, train_setup):
    """Three faults stacked on one step: repair wait doubles per repeat and
    a pre-retry checkpoint bounds the replay."""
    inj = FaultInjector(mtbf_steps=10.0, mttr_steps=4.0, seed=0,
                        horizon_steps=0)
    inj.fail_steps = collections.Counter({3: 3})
    coord = _coordinator(train_setup, tmp_path, injector=inj)
    rep = coord.run(6)
    assert rep.steps_completed == 6
    assert rep.failures == 3 and rep.restores == 3
    # streaks 1..3 at mttr=4: extra wait (2-1)*4 + (4-1)*4 = 16 steps
    assert rep.backoff_steps == pytest.approx(16.0)
    assert 3 in coord._ckpt_before        # pre-retry sync barrier installed


def test_train_slowdown_and_capacity_loss(tmp_path, train_setup):
    trace = FaultTrace(events=[
        FaultEvent(step=1, kind=SLOWDOWN, duration=5),
        FaultEvent(step=3, kind=CAPACITY_LOSS, targets=(0,), duration=4)])
    coord = _coordinator(train_setup, tmp_path, chaos=ChaosEngine(trace))
    rep = coord.run(6)
    assert rep.steps_completed == 6
    assert rep.slowdowns == 1
    assert rep.failures == 1 and rep.restores == 1   # capacity loss = outage


def test_train_disk_full_prunes_and_survives(tmp_path, train_setup):
    """disk_full + same-step crash: the forced checkpoint hits ENOSPC,
    prunes-and-retries, and the restore immediately *reads* the rewritten
    index — which must audit clean."""
    trace = FaultTrace(events=[
        FaultEvent(step=3, kind=DISK_FULL),
        FaultEvent(step=3, kind=HOST_CRASH, duration=2)])
    coord = _coordinator(train_setup, tmp_path, chaos=ChaosEngine(trace))
    rep = coord.run(8)
    assert rep.steps_completed == 8
    assert rep.disk_full_events == 1
    assert rep.enospc_retries >= 1            # the save pruned and retried
    assert rep.index_violations == 0          # committed index never torn
    assert rep.restores >= 1                  # crash read the pruned index
    assert all(np.isfinite(rep.losses))


def test_train_net_partition_parks_single_actor(tmp_path, train_setup):
    """On the single-actor coordinator a partition is the degenerate one-pod
    cluster: no quorum anywhere, so the whole cluster parks for the window —
    virtual time is lost, state and data order are not."""
    trace = FaultTrace(events=[FaultEvent(step=2, kind=NET_PARTITION,
                                          targets=(0,), duration=4)])
    coord = _coordinator(train_setup, tmp_path, chaos=ChaosEngine(trace))
    rep = coord.run(6)
    clean = _coordinator(train_setup, tmp_path, name="clean")
    ref = clean.run(6)
    assert rep.steps_completed == 6
    assert rep.partitions == 1 and rep.parked_steps == pytest.approx(4.0)
    assert rep.failures == 0 and rep.restores == 0   # no state lost
    np.testing.assert_array_equal(rep.losses, ref.losses)


# ------------------------------------------------------ serving chaos ----

def test_serve_slowdown_stalls_then_resumes_bit_identical(serve_setup):
    """A straggler worker stalls its slots without losing state: the run
    takes longer but the delivered tokens are exactly the clean run's."""
    cfg, params = serve_setup
    reqs = [_req(i, 8 + 2 * i, 10, vocab=cfg.vocab_size, seed=3)
            for i in range(2)]
    clean = _engine(cfg, params, reqs, workers=1, slots=2)
    trace = FaultTrace(events=[
        FaultEvent(step=4, kind=SLOWDOWN, targets=(0,), duration=6)])
    slow = _engine(cfg, params, reqs, workers=1, slots=2,
                   chaos=ChaosEngine(trace))
    assert slow.metrics.slowdown_events == 1
    assert slow.step_no > clean.step_no   # the stall cost real steps
    assert len(slow.completed) == len(reqs)
    for rid in clean.completed:
        assert clean.completed[rid] == slow.completed[rid], rid
    assert slow.metrics.failures == 0     # no state was lost


def test_serve_capacity_loss_sheds_hopeless_only(serve_setup):
    """Deadline-aware degraded mode: queued hedges collapse and provably
    hopeless requests are shed — but nothing past its first token."""
    cfg, params = serve_setup
    reqs = [_req(0, 8, 8, vocab=cfg.vocab_size, seed=1),
            _req(1, 8, 8, deadline=3, vocab=cfg.vocab_size, seed=1),
            _req(2, 8, 8, deadline=200, vocab=cfg.vocab_size, seed=1)]
    trace = FaultTrace(events=[
        FaultEvent(step=2, kind=CAPACITY_LOSS, targets=(1,), duration=30)])
    engine = _engine(cfg, params, reqs, workers=2, slots=1,
                     policy=uniform_policy(2), chaos=ChaosEngine(trace))
    m = engine.metrics
    assert m.capacity_events == 1
    # rid 1 can never finish by step 3 (needs >= 6 steps): shed, not run
    assert 1 in engine.shed and 1 not in engine.completed
    assert m.shed == 1 and m.records[1].shed_step is not None
    assert m.hedge_drops >= 1             # queued copies collapsed to one
    assert 0 in engine.completed and 2 in engine.completed
    assert m.past_first_token_drops == 0  # the tripwire


def test_serve_snapshot_corrupt_falls_back_to_reprefill(serve_setup):
    """A corrupted decode snapshot must fail its checksum at resume time and
    the request re-prefills from scratch — same final tokens, never garbage
    state."""
    cfg, params = serve_setup
    reqs = [_req(0, 10, 12, vocab=cfg.vocab_size, seed=5)]
    clean = _engine(cfg, params, reqs, workers=1, slots=1,
                    snapshot_lambda=3)
    trace = FaultTrace(events=[
        FaultEvent(step=6, kind=SNAPSHOT_CORRUPT, seed=123),
        FaultEvent(step=6, kind=HOST_CRASH, targets=(0,), duration=2)])
    faulty = _engine(cfg, params, reqs, workers=1, slots=1,
                     snapshot_lambda=3, chaos=ChaosEngine(trace))
    m = faulty.metrics
    assert m.snapshots_corrupted == 1
    assert m.snapshot_restore_failures == 1   # checksum caught it
    assert m.restores == 0                    # corrupt snapshot never used
    assert m.resubmissions == 1
    assert faulty.completed[0] == clean.completed[0]


def test_serve_chaos_trace_replay_is_identical(serve_setup):
    """Two runs over one recorded trace (host crashes included) produce the
    same tokens and the same counters — the record/replay guarantee."""
    cfg, params = serve_setup
    reqs = [_req(i, 6 + 3 * i, 12, vocab=cfg.vocab_size, seed=9)
            for i in range(3)]
    trace = sample_trace("unstable", horizon=80, n_targets=2, seed=5,
                         kinds=SERVE_KINDS)
    assert trace.kinds() & {HOST_CRASH}
    runs = [_engine(cfg, params, reqs, chaos=ChaosEngine(trace))
            for _ in range(2)]
    a, b = (r.metrics.summary(r.step_no) for r in runs)
    assert a == b
    assert runs[0].completed == runs[1].completed
    assert runs[0].metrics.past_first_token_drops == 0


def test_queue_depth_bound_rejects_with_retry_after():
    q = AdmissionQueue(max_depth=2, drain_rate=2.0)
    assert q.admit([WorkItem(_req(0, 4, 8))]) is None
    assert q.admit([WorkItem(_req(1, 4, 8))]) is None
    hint = q.admit([WorkItem(_req(2, 4, 8))])
    # excess of 1 item ahead of the bound: 8 tokens at 2 tok/step -> 4 steps
    assert hint == 4
    assert len(q) == 2                        # the rejected item never queued
    # resubmissions carry work already paid for: they bypass the bound
    assert q.admit([WorkItem(_req(3, 4, 8), is_resubmission=True)]) is None
    assert len(q) == 3


def test_serve_bounded_admission_under_capacity_loss(serve_setup):
    """Queue-length-priced admission: once the backlog crosses the bound,
    fresh arrivals are rejected with a retry_after hint instead of growing
    the queue without limit — and the admitted work still completes through
    a capacity-loss window."""
    cfg, params = serve_setup
    reqs = [_req(i, 8, 8, vocab=cfg.vocab_size, seed=2) for i in range(8)]
    cache_len = max(prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    pool = WorkerPool(2, 1, mtbf_steps=0.0, mttr_steps=6, seed=0)
    trace = FaultTrace(events=[FaultEvent(step=2, kind=CAPACITY_LOSS,
                                          targets=(1,), duration=30)])
    engine = ServeEngine(
        cfg, EngineConfig(cache_len=cache_len, q_chunk=32,
                          snapshot_lambda=4, max_queue_depth=4),
        pool=pool, policy=uniform_policy(2), params=params,
        chaos=ChaosEngine(trace))
    admitted = []
    for r in reqs:
        if engine.submit(r):
            admitted.append(r.rid)
        # all-or-nothing admits of rep=2 keep depth <= bound - 1 + rep
        assert len(engine.queue) <= 4 + 1
    assert admitted == [0, 1]                 # depth 4 reached after two
    m = engine.metrics
    assert m.rejected_on_arrival == 6
    assert set(engine.rejected) == {2, 3, 4, 5, 6, 7}
    assert all(hint >= 1 for hint in engine.rejected.values())
    assert m.records[2].rejected_step == 0 and m.records[2].retry_after >= 1
    assert set(engine.requests) == {0, 1}     # rejected rids never tracked
    engine.run(max_steps=2_000)
    s = m.summary(engine.step_no)
    assert m.capacity_events == 1
    assert set(engine.completed) == {0, 1}    # admitted work survives chaos
    assert s["rejected_on_arrival"] == 6.0
    assert m.past_first_token_drops == 0


def test_queue_drop_hedges_spares_resubmissions():
    q = AdmissionQueue()
    r0, r1 = _req(0, 4, 4), _req(1, 4, 4)
    q.submit(WorkItem(r0, copy_id=0))
    q.submit(WorkItem(r0, copy_id=1))
    q.submit(WorkItem(r1, copy_id=0))
    q.submit(WorkItem(r1, copy_id=0, is_resubmission=True))  # jumps head
    # r0's second copy and r1's plain copy (hedging the resubmission) go;
    # the resubmission itself and one copy per request survive
    assert q.drop_hedges() == 2
    kept = [(it.req.rid, it.is_resubmission) for it in q.items()]
    assert kept == [(1, True), (0, False)]
