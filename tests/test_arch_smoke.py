"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned architectures: one forward/train step with shape
asserts + NaN checks, plus prefill/decode consistency against the
full-sequence forward (the serving path must agree with training math).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.shapes import make_batch
from repro.models import lm

S = 24


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = get_config(arch, tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, batch=2, seq=32)
    loss, metrics = jax.jit(
        lambda p, b: lm.forward_train(p, cfg, b, q_chunk=16, xent_chunk=16)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0
    # gradients flow and are finite
    g = jax.grad(lambda p: lm.forward_train(p, cfg, batch, q_chunk=16,
                                            xent_chunk=16)[0])(params)
    flat = jax.tree.leaves(g)
    assert flat and all(bool(jnp.all(jnp.isfinite(x))) for x in flat)
    # embedding gradient is nonzero
    assert float(jnp.abs(g["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiable(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 1e8, f"{arch} param count suspiciously small: {n}"
    if cfg.is_moe:
        assert cfg.active_param_count() < n


def _ref_last_logits(params, cfg, batch, s):
    dtype = jnp.float32
    x = params["embed"].astype(dtype)[batch["tokens"]]
    enc_out = None
    if cfg.is_encdec:
        x = x + params["dec_pos"].astype(dtype)[None, :s]
        enc_out = lm._encoder(params, cfg, batch["frames"], 16)
    if cfg.n_image_tokens:
        x = jnp.concatenate([batch["image_embeds"].astype(dtype), x], 1)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    h, _ = lm.backbone(params, cfg, x, pos, enc_out=enc_out, q_chunk=16)
    w = lm.output_weights(params, cfg, dtype)
    return (h[:, -1] @ w).astype(jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode_match_forward(arch):
    cfg = dataclasses.replace(get_config(arch, tiny=True),
                              compute_dtype="float32", remat=False,
                              capacity_factor=8.0)
    params = lm.init_params(jax.random.key(1), cfg)
    batch = make_batch(cfg, batch=2, seq=S, seed=3)
    ref = _ref_last_logits(params, cfg, batch, S)

    pre_batch = {k: v for k, v in batch.items()
                 if k in ("tokens", "frames", "image_embeds")}
    logits_pre, cache = lm.prefill(params, cfg, pre_batch, cache_len=64)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(ref),
                               atol=2e-4, rtol=1e-4)

    pre2 = dict(pre_batch, tokens=batch["tokens"][:, :S - 1])
    _, cache2 = lm.prefill(params, cfg, pre2, cache_len=64)
    dec_pos = (S - 1) + (cfg.n_image_tokens or 0)
    logits_dec, new_cache = lm.decode_step(
        params, cfg, cache2, batch["tokens"][:, S - 1:S], jnp.int32(dec_pos))
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(ref),
                               atol=2e-4, rtol=1e-4)
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["rwkv6_3b", "recurrentgemma_2b"])
def test_subquadratic_decode_cache_is_constant_size(arch):
    """long_500k feasibility: decode state does not grow with seq_len."""
    cfg = get_config(arch, tiny=True)
    small = lm.init_cache(cfg, 1, 64)
    big = lm.init_cache(cfg, 1, 4096)
    size = lambda c: sum(np.prod(x.shape) for x in jax.tree.leaves(c))
    if arch == "rwkv6_3b":
        assert size(small) == size(big)
    else:  # recurrentgemma: KV window capped at cfg.window
        assert size(big) <= size(small) * (cfg.window // min(64, cfg.window) + 1)


def test_moe_capacity_drops_are_the_only_decode_divergence():
    cfg = dataclasses.replace(get_config("phi35_moe_42b", tiny=True),
                              compute_dtype="float32", remat=False)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, batch=2, seq=S)
    # low capacity -> training path drops tokens; raising it restores parity
    ref = _ref_last_logits(params, cfg, batch, S)
    cfg_hi = dataclasses.replace(cfg, capacity_factor=8.0)
    ref_hi = _ref_last_logits(params, cfg_hi, batch, S)
    _, cache = lm.prefill(params, cfg_hi,
                          {"tokens": batch["tokens"][:, :S - 1]}, 64)
    logits, _ = lm.decode_step(params, cfg_hi, cache,
                               batch["tokens"][:, S - 1:S], jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_hi),
                               atol=2e-4, rtol=1e-4)
