"""Tests for repro.serve: queue, snapshots, CRCH routing, and the engine's
failure-determinism guarantee."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (AdmissionQueue, EngineConfig, Request, ServeEngine,
                         ServeMetrics, WorkItem, WorkerPool, crch_policy,
                         engine_supported, greedy_reference, prompt_bucket,
                         request_class, request_features, uniform_policy)
from repro.serve.snapshot import cache_batch_axes, slot_get, slot_set


def _req(rid, plen, newt, *, arrival=0, deadline=None, vocab=256, seed=0,
         cfg=None):
    rng = np.random.default_rng(seed * 7919 + rid)
    frames = embeds = None
    if cfg is not None:
        vocab = cfg.vocab_size
        if cfg.is_encdec:
            frames = rng.normal(size=(cfg.n_frames, cfg.d_model)) \
                        .astype(np.float32)
        if cfg.n_image_tokens:
            embeds = rng.normal(size=(cfg.n_image_tokens, cfg.d_model)) \
                        .astype(np.float32)
    return Request(rid=rid,
                   prompt=rng.integers(1, vocab, plen,
                                       dtype=np.int64).astype(np.int32),
                   max_new_tokens=newt, arrival=arrival, deadline=deadline,
                   frames=frames, image_embeds=embeds)


# ---------------------------------------------------------------- queue ----

def test_prompt_bucket_next_pow2():
    assert prompt_bucket(1) == 8
    assert prompt_bucket(8) == 8
    assert prompt_bucket(9) == 16
    assert prompt_bucket(33) == 64


def test_request_class_buckets():
    c = request_class(_req(0, 13, 20))
    assert (c.prompt_bucket, c.new_bucket) == (16, 32)


def test_request_features_shape_and_slack():
    reqs = [_req(0, 8, 8, deadline=100), _req(1, 16, 32)]
    feats = request_features(reqs)
    assert feats.shape == (2, 10)
    assert feats[0, 4] == 100 - 16          # deadline slack
    assert np.isfinite(feats).all()         # no deadline -> capped, not inf


def test_admission_queue_resubmission_jumps_head_and_cancel():
    q = AdmissionQueue()
    q.submit(WorkItem(_req(0, 8, 8)))
    q.submit(WorkItem(_req(1, 8, 8)))
    q.submit(WorkItem(_req(2, 8, 8), is_resubmission=True))
    assert q.pop().req.rid == 2
    assert q.cancel(1) == 1
    assert q.pending_rids() == {0}
    # pop with a predicate skips inadmissible items without dropping them
    assert q.pop(lambda it: it.req.rid == 99) is None
    assert len(q) == 1


# ------------------------------------------------------------- replicas ----

def test_crch_policy_hedges_failure_prone_class_more():
    """The long-decode outlier class must get a strictly larger hedging
    budget than the dominant short class (and than no-replication)."""
    reqs = ([_req(i, 8, 8, seed=1) for i in range(24)] +
            [_req(100 + i, 30, 64, seed=1) for i in range(4)])
    pol = crch_policy(reqs, max_rep=3)
    short_rep = pol.rep_for(reqs[0])
    long_rep = pol.rep_for(reqs[-1])
    assert short_rep == 1
    assert long_rep > short_rep
    assert long_rep > uniform_policy(1).rep_for(reqs[-1])
    assert long_rep <= 3


def test_worker_pool_failure_and_repair():
    pool = WorkerPool(2, 2, mtbf_steps=0.0, mttr_steps=5, seed=0)
    assert pool.worker_of(3) == 1
    assert list(pool.slots_of(0)) == [0, 1]
    pool.force_failure(10, wid=0)
    assert pool.step_failures(10) == [0]
    assert not pool.is_up(0, 12)
    assert pool.is_up(0, 15)
    assert pool.is_up(1, 12)


# -------------------------------------------------------------- snapshot ----

@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-3b", "recurrentgemma-2b",
                                  "whisper-small"])
def test_slot_get_set_roundtrip(arch):
    """Row extraction/insertion must be exact for every cache pytree shape:
    dense KV, RWKV recurrent state, RG-LRU hybrid, enc-dec cross-KV."""
    cfg = get_config(arch, tiny=True)
    cache = lm.init_cache(cfg, 3, 16)
    axes = cache_batch_axes(cfg, 16)
    marked = jax.tree.map(lambda l: l + 1.0, cache)
    row = slot_get(marked, axes, 1)
    out = slot_set(cache, axes, 1, row)
    for leaf, a, want in zip(jax.tree.leaves(out), jax.tree.leaves(axes),
                             jax.tree.leaves(marked)):
        got = np.moveaxis(np.asarray(leaf), a, 0)
        ref = np.moveaxis(np.asarray(want), a, 0)
        np.testing.assert_array_equal(got[1], ref[1])   # written row
        assert (got[0] == 0).all() and (got[2] == 0).all()  # untouched


# --------------------------------------------------------------- metrics ----

def test_metrics_wastage_accounting():
    m = ServeMetrics()
    r = _req(0, 10, 10, deadline=50)
    m.register(r)
    m.prefill_tokens += 16
    m.decode_tokens += 10
    m.snapshot_overhead_tokens += 2.0
    m.complete(0, 30)
    s = m.summary(100)
    assert s["completed"] == 1
    assert s["in_deadline"] == 1
    assert s["usage_tokens"] == 28
    assert s["wasted_tokens"] == 28 - 20
    assert s["p50_latency"] == 30


# ---------------------------------------------------------------- engine ----

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("olmo-1b", tiny=True)
    ok, why = engine_supported(cfg)
    assert ok, why
    params = lm.init_params(jax.random.key(0), cfg)
    return cfg, params


def _cache_len_for(cfg, reqs):
    offset = cfg.n_image_tokens or 0
    cache_len = max(offset + prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    if cfg.rglru and cfg.window:
        cache_len = max(cache_len, cfg.window)
    return cache_len


def _run_engine(cfg, params, reqs, *, fail=None, snapshot_lambda=4,
                policy=None, retain_completed=4096):
    cache_len = _cache_len_for(cfg, reqs)
    pool = WorkerPool(2, 2, mtbf_steps=0.0, mttr_steps=6, seed=0)
    if fail is not None:
        pool.force_failure(fail[0], wid=fail[1])
    engine = ServeEngine(
        cfg, EngineConfig(cache_len=cache_len, q_chunk=32,
                          snapshot_lambda=snapshot_lambda,
                          retain_completed=retain_completed),
        pool=pool, policy=policy or uniform_policy(1), params=params)
    for r in reqs:
        engine.submit(r)
    engine.run(max_steps=2_000)
    return engine


def test_engine_failure_resume_matches_failure_free(tiny_setup):
    """Mid-decode worker failure + snapshot resume must reproduce the
    failure-free greedy tokens exactly (Algorithm 3's correctness bar)."""
    cfg, params = tiny_setup
    reqs = [_req(i, 8 + 3 * i, 16, vocab=cfg.vocab_size, seed=3)
            for i in range(4)]
    clean = _run_engine(cfg, params, reqs)
    faulty = _run_engine(cfg, params, reqs, fail=(9, 0))
    assert len(clean.completed) == len(reqs)
    assert len(faulty.completed) == len(reqs)
    assert faulty.metrics.failures >= 1
    assert faulty.metrics.resubmissions >= 1
    for rid in clean.completed:
        assert clean.completed[rid] == faulty.completed[rid], rid


def test_engine_replicated_requests_survive_single_worker_loss(tiny_setup):
    """With a replica on each worker, killing one worker must not trigger a
    resubmission — the surviving copy delivers."""
    cfg, params = tiny_setup
    reqs = [_req(0, 12, 16, vocab=cfg.vocab_size, seed=5)]
    engine = _run_engine(cfg, params, reqs, fail=(6, 0),
                         policy=uniform_policy(2))
    assert engine.completed and engine.metrics.failures >= 1
    assert engine.metrics.resubmissions == 0


def test_engine_rejects_oversized_request(tiny_setup):
    cfg, params = tiny_setup
    engine_req = _req(0, 8, 8, vocab=cfg.vocab_size)
    cache_len = 16
    pool = WorkerPool(1, 2, mtbf_steps=0.0, seed=0)
    engine = ServeEngine(cfg, EngineConfig(cache_len=cache_len, q_chunk=32),
                         pool=pool, policy=uniform_policy(1), params=params)
    engine.submit(engine_req)
    with pytest.raises(ValueError):
        engine.submit(_req(1, 20, 16, vocab=cfg.vocab_size))


def test_engine_supports_all_families():
    """The family gate is gone: the continuous engine drives every arch."""
    for arch in ("olmo-1b", "rwkv6-3b", "recurrentgemma-2b",
                 "whisper-small", "llava-next-mistral-7b"):
        ok, why = engine_supported(get_config(arch, tiny=True))
        assert ok, f"{arch}: {why}"


def test_engine_idle_slot_cache_row_untouched(tiny_setup):
    """A freed slot's cache row must stay bit-identical while other slots
    keep decoding — stale last_token/pos must be masked out of the batched
    cache write (regression: recurrent state accumulates corruption)."""
    cfg, params = tiny_setup
    reqs = [_req(0, 8, 3, vocab=cfg.vocab_size, seed=9),
            _req(1, 8, 24, vocab=cfg.vocab_size, seed=9)]
    cache_len = _cache_len_for(cfg, reqs)
    pool = WorkerPool(2, 2, mtbf_steps=0.0, seed=0)
    engine = ServeEngine(cfg, EngineConfig(cache_len=cache_len, q_chunk=32),
                         pool=pool, policy=uniform_policy(1), params=params)
    for r in reqs:
        engine.submit(r)
    while 0 not in engine.completed:
        engine.step()
    freed = [s.sid for s in engine.slots if not s.busy]
    assert freed and any(s.busy for s in engine.slots)
    before = {sid: jax.device_get(engine._get(engine.cache, sid))
              for sid in freed}
    for _ in range(6):
        engine.step()
    for sid in freed:
        after = jax.device_get(engine._get(engine.cache, sid))
        for a, b in zip(jax.tree.leaves(before[sid]),
                        jax.tree.leaves(after)):
            np.testing.assert_array_equal(a, b)


def test_worker_pool_mid_mttr_failure_deferred_not_lost():
    """A sampled failure landing while the worker is already down must not
    be silently absorbed: it strikes again at repair completion."""
    pool = WorkerPool(1, 1, mtbf_steps=1e9, mttr_steps=10, seed=0)
    inj = pool.injectors[0]
    inj.fail_steps = {5, 8}
    assert pool.step_failures(5) == [0]
    assert not pool.is_up(0, 8)
    assert pool.step_failures(8) == []      # mid-MTTR: deferred, not dropped
    assert 8 not in inj.fail_steps
    assert 15 in inj.fail_steps             # rescheduled to repair step
    assert pool.step_failures(15) == [0]    # strikes again once repaired


def test_engine_state_bounded_over_many_requests(tiny_setup):
    """A long-running service must not grow host state without bound:
    completed/request/snapshot entries are evicted FIFO beyond
    ``retain_completed`` and ``active`` never retains empty sets."""
    cfg, params = tiny_setup
    n = 1_000
    reqs = [_req(i, 6, 2, vocab=cfg.vocab_size, seed=11) for i in range(n)]
    engine = _run_engine(cfg, params, reqs, retain_completed=64)
    assert engine.metrics.summary(engine.step_no)["completed"] == n
    assert len(engine.completed) <= 64
    assert len(engine.requests) <= 64
    assert len(engine._completed_order) <= 64
    assert engine.active == {}
    assert len(engine.store) == 0
    # the newest requests are the retained ones
    assert max(engine.completed) == n - 1


ALL_ARCHS = ("rwkv6-3b", "recurrentgemma-2b", "whisper-small",
             "llava-next-mistral-7b")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_engine_token_parity_with_static_reference(arch):
    """Continuous batching must be output-transparent for every family:
    engine tokens == batch=1 exact-length static greedy tokens."""
    cfg = get_config(arch, tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    reqs = [_req(i, 5 + 2 * i, 8, seed=13, cfg=cfg) for i in range(4)]
    engine = _run_engine(cfg, params, reqs)
    assert len(engine.completed) == len(reqs)
    ref = greedy_reference(params, cfg, reqs, _cache_len_for(cfg, reqs),
                           q_chunk=32)
    for r in reqs:
        assert engine.output(r.rid) == ref[r.rid], r.rid


def test_engine_rwkv_failure_resume_matches_failure_free():
    """Recurrent-state snapshot restore must reproduce the failure-free
    greedy tokens exactly (the state is NOT reconstructible from the KV
    overwrite argument — the snapshot itself must be exact)."""
    cfg = get_config("rwkv6-3b", tiny=True)
    params = lm.init_params(jax.random.key(1), cfg)
    reqs = [_req(i, 7 + 3 * i, 16, seed=17, cfg=cfg) for i in range(4)]
    clean = _run_engine(cfg, params, reqs)
    faulty = _run_engine(cfg, params, reqs, fail=(9, 0))
    assert len(faulty.completed) == len(reqs)
    assert faulty.metrics.failures >= 1
    assert faulty.metrics.resubmissions >= 1
    for rid in clean.completed:
        assert clean.completed[rid] == faulty.completed[rid], rid
