"""Validation of the roofline analysis machinery.

1. XLA's cost_analysis counts while-loop bodies once (the reason we use an
   analytic FLOP model) -- demonstrated directly.
2. The analytic FLOP model matches cost_analysis on *unrolled* (scan-free)
   forwards within tolerance.
3. The HLO collective parser scales loop-nested collectives by trip count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import flops as F
from repro.analysis import hlo as H
from repro.configs import get_config
from repro.launch.shapes import Shape
from repro.models import lm
from repro.models.config import ModelConfig


def test_cost_analysis_counts_scan_bodies_once():
    def scan_fn(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    def unroll_fn(x, w):
        for i in range(8):
            x = x @ w[i]
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    fs = H.normalize_cost_analysis(
        jax.jit(scan_fn).lower(x, w).compile().cost_analysis())["flops"]
    fu = H.normalize_cost_analysis(
        jax.jit(unroll_fn).lower(x, w).compile().cost_analysis())["flops"]
    assert fu == pytest.approx(8 * fs, rel=0.01)


def _unrolled_last_logits(params, cfg, batch):
    """Scan-free forward (prefill semantics: last-token logits)."""
    dtype = jnp.float32
    x = params["embed"].astype(dtype)[batch["tokens"]]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    L = jax.tree.leaves(params["layers"])[0].shape[0]
    for i in range(L):
        layer = jax.tree.map(lambda p: p[i], params["layers"])
        x, _ = lm._dense_block(layer, x, cfg, pos, q_chunk=x.shape[1])
    x = lm.apply_norm(cfg, params["final_norm"], x)
    w = lm.output_weights(params, cfg, dtype)
    return (x[:, -1] @ w).astype(jnp.float32)


@pytest.mark.parametrize("arch,rel", [("olmo_1b", 0.35),
                                      ("phi35_moe_42b", 0.45)])
def test_analytic_flops_match_unrolled_hlo(arch, rel):
    cfg = dataclasses.replace(
        get_config(arch, tiny=True), n_layers=3, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=2048,
        compute_dtype="float32", remat=False)
    b, s = 2, 256
    params = jax.eval_shape(lambda: lm.init_params(jax.random.key(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    compiled = jax.jit(
        lambda p, bt: _unrolled_last_logits(p, cfg, bt)).lower(
        params, batch).compile()
    hlo_flops = H.normalize_cost_analysis(compiled.cost_analysis())["flops"]
    shape = Shape("prefill_test", "prefill", s, b)
    analytic = F.cell_flops(cfg, shape).flops
    assert analytic == pytest.approx(hlo_flops, rel=rel), \
        f"analytic {analytic:.3g} vs HLO {hlo_flops:.3g}"


def test_model_flops_ratio_sane():
    cfg = get_config("deepseek_coder_33b")
    from repro.launch.shapes import SHAPES
    cost = F.cell_flops(cfg, SHAPES["train_4k"])
    # 6ND is a lower bound on compiled work: attention + remat push above it
    assert cost.flops > cost.model_flops
    assert cost.model_flops / cost.flops > 0.3


SYNTH_HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ag = f32[128,256] all-gather(%x), replica_groups={}, dimensions={0}
  %ar = f32[128,256] all-reduce(%ag), to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ip, %ar)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]) tuple(%zero, %x)
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  %y = f32[128,256] get-tuple-element(%w), index=1
  ROOT %out = f32[128,256] all-gather(%y), replica_groups={}, dimensions={0}
}
"""


def test_hlo_collective_parser_scales_by_trip_count():
    totals = H.collective_totals(SYNTH_HLO)
    assert totals["scaled"]
    tensor = 128 * 256 * 4
    # all-gather: 24 in-loop + 1 at top level; all-reduce: 24 in-loop
    assert totals["bytes"]["all-gather"] == 25 * tensor
    assert totals["bytes"]["all-reduce"] == 24 * tensor
    assert totals["counts"]["all-gather"] == 25
    assert H.link_bytes(totals) == pytest.approx(
        25 * tensor + 2.0 * 24 * tensor)


def test_hlo_parser_on_real_dryrun_artifact():
    import glob
    import os
    files = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                   "benchmarks", "out", "dryrun",
                                   "*train_4k__single.hlo.gz"))
    if not files:
        pytest.skip("no dry-run artifacts present")
    totals = H.collective_totals(H.load_hlo(files[0]))
    assert totals["scaled"]
    assert sum(totals["bytes"].values()) > 0
    # scaled totals must exceed a flat (body-once) grep
    flat = H.parse_computations(H.load_hlo(files[0]))[0]
    flat_sum = sum(sum(c.coll_bytes.values()) for c in flat.values())
    assert sum(totals["bytes"].values()) >= flat_sum
