"""Unit + property tests for the triplet agglomerative clustering."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pairwise_distances, replication_counts, triplet_agglomerate
from repro.kernels.pairwise_affinity import ref as pa_ref


def _blobs(rng, centers, n_per, dim=4, spread=0.1):
    pts = []
    for c in centers:
        pts.append(rng.normal(scale=spread, size=(n_per, dim)) + np.asarray(c))
    return np.concatenate(pts)


def test_recovers_well_separated_blobs():
    rng = np.random.default_rng(0)
    centers = [np.zeros(4), np.full(4, 10.0), np.full(4, -10.0)]
    pts = _blobs(rng, centers, 20)
    res = triplet_agglomerate(pts, n_clusters=3, R=3, lam=0.5)
    labels = res.labels
    # each blob is pure: all 20 points of a blob share one label
    for b in range(3):
        blob_labels = labels[b * 20:(b + 1) * 20]
        assert len(set(blob_labels.tolist())) == 1
    assert sorted(res.cluster_sizes) == [20, 20, 20]


def test_replication_counts_by_size_rank():
    rng = np.random.default_rng(1)
    pts = np.concatenate([
        rng.normal(scale=0.1, size=(30, 3)),
        rng.normal(scale=0.1, size=(10, 3)) + 8.0,
        rng.normal(scale=0.1, size=(4, 3)) - 8.0,
    ])
    res = triplet_agglomerate(pts, n_clusters=3)
    counts = replication_counts(res)
    # biggest cluster -> 1 copy, middle -> 2, outliers -> 3
    assert counts[:30].tolist() == [1] * 30
    assert counts[30:40].tolist() == [2] * 10
    assert counts[40:].tolist() == [3] * 4


def test_rule_guard_caps_lowly_outliers():
    rng = np.random.default_rng(2)
    pts = np.concatenate([
        rng.normal(scale=0.1, size=(30, 3)),
        rng.normal(scale=0.1, size=(3, 3)) + 9.0,
        rng.normal(scale=0.1, size=(2, 3)) - 9.0,
    ])
    res = triplet_agglomerate(pts, n_clusters=3)
    pri = np.zeros(35)
    ext = np.zeros(35)
    counts = replication_counts(res, rule_guard=True, priorities=pri,
                                exec_times=ext)
    assert counts.max() <= 2


def test_dendrogram_threshold_stops_early():
    rng = np.random.default_rng(3)
    pts = np.concatenate([
        rng.normal(scale=0.05, size=(10, 2)),
        rng.normal(scale=0.05, size=(10, 2)) + 100.0,
    ])
    res = triplet_agglomerate(pts, n_clusters=1, dendro_threshold=10.0)
    # refuses to merge the two distant blobs into one supercluster
    assert len(res.cluster_sizes) == 2


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 24),
    dim=st.integers(1, 6),
    k=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_property_cluster_invariants(n, dim, k, seed):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, dim))
    res = triplet_agglomerate(pts, n_clusters=k)
    assert sum(res.cluster_sizes) == n
    assert len(res.cluster_sizes) == min(k, n)
    assert res.labels.min() >= 0 and res.labels.max() < min(k, n)
    counts = replication_counts(res)
    assert counts.min() >= 1 and counts.max() <= min(k, n)
    # counts are anti-monotone in cluster size rank
    sizes = np.asarray(res.cluster_sizes)
    for c1 in range(len(sizes)):
        for c2 in range(len(sizes)):
            if sizes[c1] > sizes[c2]:
                t1 = np.where(res.labels == c1)[0][0]
                t2 = np.where(res.labels == c2)[0][0]
                assert counts[t1] <= counts[t2]


def test_pairwise_distance_ref_matches_numpy():
    rng = np.random.default_rng(4)
    pts = rng.normal(size=(37, 5)).astype(np.float32)
    d_ref = np.asarray(pa_ref.pairwise_distance(pts))
    d_np = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
    # fp32 gram-expansion rounding puts ~sqrt(eps) noise on near-zero cells
    np.testing.assert_allclose(d_ref, d_np, atol=3e-3)
    d_core = pairwise_distances(pts)
    np.testing.assert_allclose(d_core, d_np, atol=3e-3)
