"""Partition-tolerant cross-pod exchange tests: quorum election, tie park,
minority catch-up bit-identity, residual hygiene on membership change."""
import jax
import numpy as np
import pytest

from repro.chaos import NET_PARTITION, ChaosEngine, FaultEvent, FaultTrace
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.ft import (CheckpointStore, PodGradientExchange,
                      PodTrainingCluster, tree_digest)
from repro.models import lm


def _grad(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((16, 16)).astype(np.float32)}


@pytest.fixture(scope="module")
def cluster_setup():
    cfg = get_config("olmo_1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    return cfg, params


def _make_cluster(cfg, params, tmpdir, *, chaos=None, n_pods=3):
    return PodTrainingCluster(
        cfg=cfg, params=params,
        pipeline=SyntheticTokenPipeline(DataConfig(2, 32, seed=0), cfg),
        store=CheckpointStore(str(tmpdir)), n_pods=n_pods, ckpt_every=3,
        chaos=chaos)


# ---------------------------------------------------------------------------
# quorum election over the link matrix
# ---------------------------------------------------------------------------
def test_quorum_election_3_pods_minority_cut():
    ex = PodGradientExchange(n_pods=3)
    assert ex.current_quorum() == (0, 1, 2)
    ex.partition({2})
    assert ex.components() == [(0, 1), (2,)]
    assert ex.current_quorum() == (0, 1)
    res = ex.round([_grad(), _grad(), None])   # parked pod's grads unread
    assert res.quorum == (0, 1) and res.parked == (2,)
    assert res.avg is not None and res.fingerprint


def test_quorum_election_4_pods():
    ex = PodGradientExchange(n_pods=4)
    ex.partition({3})
    assert ex.current_quorum() == (0, 1, 2)    # 3 of 4 is a strict majority
    ex.partition({2})                           # now 2 of 4: a tie
    assert ex.current_quorum() is None
    ex.restore_pods({2})
    assert ex.current_quorum() == (0, 1, 2)


def test_no_majority_tie_parks_whole_cluster():
    ex = PodGradientExchange(n_pods=2)
    ex.partition({1})                           # 1 of 2 each side: no quorum
    res = ex.round([_grad(), _grad(1)])
    assert res.avg is None and res.fingerprint is None
    assert res.quorum == () and res.parked == (0, 1)
    assert ex.parked_pod_rounds == 2
    with pytest.raises(RuntimeError, match="no quorum"):
        ex.exchange([_grad(), _grad(1)])
    ex.restore_pods({1})                        # heal: full cluster again
    assert ex.current_quorum() == (0, 1)


def test_split_brain_fingerprint_detection():
    ex = PodGradientExchange(n_pods=3)
    assert ex.check_round_fingerprints(0, {0: "aa", 1: "aa", 2: "aa"})
    assert ex.split_brain_divergences == 0
    assert not ex.check_round_fingerprints(1, {0: "aa", 1: "bb"})
    assert ex.split_brain_divergences == 1


# ---------------------------------------------------------------------------
# residual hygiene on membership change
# ---------------------------------------------------------------------------
def test_rejoining_pod_adopts_quorum_residual_not_stale_one():
    ex = PodGradientExchange(n_pods=3)
    g = _grad()
    ex.round([g, g, g])                        # all residuals now nonzero
    stale = ex.residuals[2]
    assert any(np.abs(np.asarray(leaf)).max() > 0
               for leaf in jax.tree.leaves(stale))
    ex.partition({2})
    ex.round([g, g, None])                     # quorum residuals advance
    ex.round([g, g, None])
    assert tree_digest(ex.residuals[2]) == tree_digest(stale)  # frozen
    ex.restore_pods({2})
    # membership change: stale residual is reset, quorum's adopted
    ex.reset_residual(2)
    assert all(np.abs(np.asarray(leaf)).max() == 0
               for leaf in jax.tree.leaves(ex.residuals[2]))
    ex.set_residual(2, ex.residuals[0])
    assert tree_digest(ex.residuals[2]) == tree_digest(ex.residuals[0])
    assert tree_digest(ex.residuals[2]) != tree_digest(stale)


# ---------------------------------------------------------------------------
# minority catch-up: bit-identical to the unpartitioned run after heal
# ---------------------------------------------------------------------------
def test_partitioned_then_healed_matches_fault_free_run(tmp_path,
                                                        cluster_setup):
    cfg, params = cluster_setup
    n_steps = 8
    trace = FaultTrace(events=[FaultEvent(step=2, kind=NET_PARTITION,
                                          targets=(2,), duration=3, seed=0)])
    faulty = _make_cluster(cfg, params, tmp_path / "a",
                           chaos=ChaosEngine(trace))
    rep = faulty.run(n_steps)
    clean = _make_cluster(cfg, params, tmp_path / "b")
    ref = clean.run(n_steps)

    assert rep.steps_completed == ref.steps_completed == n_steps
    assert rep.partitions == 1 and rep.heals == 1 and rep.catchups == 1
    assert rep.parked_pod_rounds > 0
    assert rep.split_brain_divergences == 0
    assert rep.index_violations == 0
    # the acceptance property: every pod (including the healed minority
    # pod 2) lands bit-identical to the fault-free cluster
    ref_digest = tree_digest(clean.params[0])
    for p in range(3):
        assert tree_digest(faulty.params[p]) == ref_digest, f"pod {p}"
    # healed pod adopted the quorum's residual, not its stale one
    assert (tree_digest(faulty.exchange.residuals[2]) ==
            tree_digest(faulty.exchange.residuals[0]))
    np.testing.assert_allclose(rep.losses, ref.losses)


def test_heal_after_target_step_catches_lowest_index_pod_up(tmp_path,
                                                            cluster_setup):
    """Regression: pod 0 is partitioned and the window outlives the run, so
    the heal drains at loop exit.  The catch-up commit must be authored by
    an up-to-date quorum member — never the rejoined stale pod, even when
    it has the lowest index."""
    cfg, params = cluster_setup
    trace = FaultTrace(events=[FaultEvent(step=3, kind=NET_PARTITION,
                                          targets=(0,), duration=50,
                                          seed=0)])
    faulty = _make_cluster(cfg, params, tmp_path / "a",
                           chaos=ChaosEngine(trace))
    rep = faulty.run(6)
    clean = _make_cluster(cfg, params, tmp_path / "b")
    clean.run(6)
    assert rep.steps_completed == 6
    assert rep.heals == 1 and rep.catchups == 1   # drained at loop exit
    ref_digest = tree_digest(clean.params[0])
    for p in range(3):
        assert tree_digest(faulty.params[p]) == ref_digest, f"pod {p}"


def test_whole_cluster_park_loses_rounds_not_batches(tmp_path,
                                                     cluster_setup):
    """Partitioning both non-lead pods of 3 leaves no majority: everyone
    parks for the window, then training resumes on the *next* batch —
    wall-clock rounds are lost, data order is not."""
    cfg, params = cluster_setup
    trace = FaultTrace(events=[FaultEvent(step=1, kind=NET_PARTITION,
                                          targets=(1, 2), duration=2,
                                          seed=0)])
    cluster = _make_cluster(cfg, params, tmp_path / "a",
                            chaos=ChaosEngine(trace))
    rep = cluster.run(4)
    clean = _make_cluster(cfg, params, tmp_path / "b")
    ref = clean.run(4)
    assert rep.steps_completed == 4
    assert rep.rounds > ref.rounds          # parked rounds consumed wall clock
    assert rep.split_brain_divergences == 0
    ref_digest = tree_digest(clean.params[0])
    assert all(tree_digest(cluster.params[p]) == ref_digest
               for p in range(3))
