"""Tests for the Lemma 3.1 dynamic checkpoint-interval model."""
import numpy as np
import pytest

from repro.core import (CloudEnvironment, generate_workflow, heft_schedule,
                        checkpoint_policy)
from repro.core.failures import ENVIRONMENTS


@pytest.fixture(scope="module")
def sched():
    wf = generate_workflow("montage", 100, seed=0)
    env = CloudEnvironment(wf, 20, seed=1)
    return heft_schedule(wf, env, 1)


def test_model_tet_positive_and_finite(sched):
    for envname in ("stable", "normal", "unstable"):
        for lam in (5.0, 50.0, 500.0):
            tet = checkpoint_policy.model_tet(
                lam, sched, ENVIRONMENTS[envname], gamma=2.0)
            assert np.isfinite(tet) and tet > 0


def test_small_lambda_penalized_by_overhead(sched):
    env = ENVIRONMENTS["stable"]
    t_small = checkpoint_policy.model_tet(1.0, sched, env, gamma=2.0)
    t_large = checkpoint_policy.model_tet(500.0, sched, env, gamma=2.0)
    # in a stable environment Term2 dominates: tiny lambda is bad (Lemma 3.1)
    assert t_small > t_large


def test_optimal_lambda_decreases_with_instability(sched):
    lams = {e: checkpoint_policy.optimal_lambda(
        sched, ENVIRONMENTS[e], gamma=2.0) for e in
        ("stable", "normal", "unstable")}
    assert lams["unstable"] <= lams["normal"] <= lams["stable"]
    assert lams["unstable"] < lams["stable"]  # strictly environment-dependent


def test_optimal_lambda_increases_with_gamma(sched):
    env = ENVIRONMENTS["unstable"]
    lam_cheap = checkpoint_policy.optimal_lambda(sched, env, gamma=0.5)
    lam_costly = checkpoint_policy.optimal_lambda(sched, env, gamma=8.0)
    assert lam_costly >= lam_cheap


def test_model_is_quasiconvex_on_grid(sched):
    env = ENVIRONMENTS["unstable"]
    grid = np.geomspace(2.0, 600.0, 25)
    vals = [checkpoint_policy.model_tet(l, sched, env, gamma=2.0)
            for l in grid]
    i_min = int(np.argmin(vals))
    # decreasing to the left of the argmin, increasing to the right
    assert all(vals[i] >= vals[i + 1] - 1e-9 for i in range(i_min))
    assert all(vals[i] <= vals[i + 1] + 1e-9 for i in range(i_min, len(vals) - 1))
