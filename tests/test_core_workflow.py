"""Unit tests: workflow DAGs, environments, features, PCA."""
import numpy as np
import pytest

from repro.core import (CloudEnvironment, FEATURE_NAMES, Task, Workflow,
                        WORKFLOW_TYPES, b_levels, fit_pca, generate_workflow,
                        task_features)


@pytest.mark.parametrize("kind", WORKFLOW_TYPES)
@pytest.mark.parametrize("n", [100, 300])
def test_generators_produce_valid_dags(kind, n):
    wf = generate_workflow(kind, n, seed=0)
    assert 0.5 * n <= wf.n_tasks <= 1.5 * n
    order = wf.topo_order()           # raises on cycles
    assert len(order) == wf.n_tasks
    pos = {t: i for i, t in enumerate(order)}
    for child, parent, d in wf.deps:
        assert pos[parent] < pos[child]
        assert d > 0
    assert wf.entry_tasks() and wf.exit_tasks()


def test_workflow_rejects_cycles():
    tasks = [Task(0, "a", 1.0), Task(1, "b", 1.0)]
    with pytest.raises(ValueError):
        Workflow("cyc", tasks, [(0, 1, 1.0), (1, 0, 1.0)])


def test_environment_matrices():
    wf = generate_workflow("montage", 100, seed=0)
    env = CloudEnvironment(wf, 20, seed=1)
    assert env.time_on_vm.shape == (wf.n_tasks, 20)
    assert (env.time_on_vm > 0).all()
    # transfer matrix symmetric with inf diagonal (dedicated 2-way lines)
    assert np.isinf(np.diag(env.transfer_rate)).all()
    off = ~np.eye(20, dtype=bool)
    assert np.allclose(env.transfer_rate[off], env.transfer_rate.T[off])
    assert env.transfer_time(10.0, 3, 3) == 0.0
    assert env.transfer_time(10.0, 3, 4) > 0.0


def test_features_shape_and_blevel_monotonicity():
    wf = generate_workflow("ligo", 100, seed=0)
    env = CloudEnvironment(wf, 20, seed=1)
    feats = task_features(wf, env)
    assert feats.shape == (wf.n_tasks, len(FEATURE_NAMES))
    assert np.isfinite(feats).all()
    # B-level of a parent strictly exceeds each of its children's
    bl = b_levels(wf, env)
    for child, parent, _ in wf.deps:
        assert bl[parent] > bl[child]


def test_pca_components_orthonormal_and_cov_reached():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(100, 10)) * np.array([5, 3, 1] + [0.1] * 7)
    res = fit_pca(x, threshold=0.8)
    k = res.components.shape[0]
    gram = res.components @ res.components.T
    np.testing.assert_allclose(gram, np.eye(k), atol=1e-4)
    assert res.cov >= 0.8 or k == 10
    assert res.projected.shape == (100, k)
    # higher threshold keeps at least as many components
    res2 = fit_pca(x, threshold=0.95)
    assert res2.components.shape[0] >= k
