"""Unit + property tests: HEFT schedules and the CheckpointHEFT runtime."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (CRCHConfig, CloudEnvironment, SimConfig, CkptLevel,
                        baselines, generate_workflow, heft_schedule,
                        metrics_from_result, plan, sample_failure_trace,
                        sim_config, simulate)
from repro.core.failures import ENVIRONMENTS, FailureTrace


def _setup(kind="montage", n=100, seed=0):
    wf = generate_workflow(kind, n, seed=seed)
    env = CloudEnvironment(wf, 20, seed=seed + 1)
    return wf, env


# ---------------------------------------------------------------------------
# HEFT schedule validity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["montage", "ligo", "cybershake", "sipht"])
def test_heft_schedule_valid(kind):
    wf, env = _setup(kind)
    sched = heft_schedule(wf, env, 1)
    placements = {p.task: p for p in sched.placements}
    assert len(placements) == wf.n_tasks
    # dependencies respected (incl. transfer times)
    for child, parent, d in wf.deps:
        pc, pp = placements[child], placements[parent]
        assert pc.est >= pp.eft + env.transfer_time(d, pp.vm, pc.vm) - 1e-6
    # no overlapping intervals on any VM
    for vm, plist in sched.by_vm.items():
        for a, b in zip(plist, plist[1:]):
            assert b.est >= a.eft - 1e-9
    # durations match the runtime matrix
    for p in sched.placements:
        assert p.duration == pytest.approx(env.time_on_vm[p.task, p.vm])


def test_replicas_on_distinct_vms_and_after_original():
    wf, env = _setup("montage")
    counts = np.full(wf.n_tasks, 3)
    sched = heft_schedule(wf, env, counts)
    for t in range(wf.n_tasks):
        copies = sched.by_task[t]
        assert len(copies) == 3
        assert len({p.vm for p in copies}) == 3
        orig = copies[0]
        for rep in copies[1:]:
            assert rep.est >= orig.eft  # standby slots after the original


def test_critical_path_valid():
    wf, env = _setup("ligo")
    sched = heft_schedule(wf, env, 1)
    cp = sched.critical_path()
    assert cp[0] in wf.entry_tasks()
    assert sched.original(cp[-1]).eft == pytest.approx(sched.makespan)
    for a, b in zip(cp, cp[1:]):
        assert a in [p for p, _ in wf.parents[b]]


# ---------------------------------------------------------------------------
# Runtime semantics
# ---------------------------------------------------------------------------
def _no_failure_trace(n_vms=20):
    return FailureTrace(env=ENVIRONMENTS["stable"], n_vms=n_vms,
                        failing_vms=[], downtime={})


def test_no_failures_matches_schedule():
    wf, env = _setup("montage")
    sched = heft_schedule(wf, env, 1)
    res = simulate(sched, _no_failure_trace(), baselines.heft_sim_config())
    assert res.completed
    assert res.n_failures == 0 and res.n_resubmissions == 0
    assert res.wastage == 0.0
    # work-conserving runtime can only beat the (insertion-based) plan
    assert res.tet <= sched.makespan * 1.05
    total_work = sum(p.duration for p in sched.placements)
    assert res.usage == pytest.approx(total_work, rel=1e-6)


def test_heft_fails_without_fault_tolerance():
    wf, env = _setup("montage")
    sched = heft_schedule(wf, env, 1)
    failed = completed = 0
    for seed in range(12):
        tr = sample_failure_trace("unstable", 20, horizon_s=40_000, seed=seed)
        res = simulate(sched, tr, baselines.heft_sim_config())
        failed += (not res.completed)
        completed += res.completed
        if not res.completed:
            assert res.wastage == pytest.approx(res.usage)  # all futile
    assert failed > 0  # the paper: HEFT cannot survive unstable environments


def test_crch_completes_under_unstable_failures():
    wf, env = _setup("montage")
    cfg = CRCHConfig()
    p = plan(wf, env, cfg, environment="unstable")
    for seed in range(8):
        tr = sample_failure_trace("unstable", 20, horizon_s=200_000,
                                  seed=seed)
        res = simulate(p.schedule, tr, sim_config(p, cfg))
        assert res.completed, f"CRCH failed on trace seed {seed}"


def test_checkpoint_overhead_accounting():
    wf, env = _setup("montage")
    sched = heft_schedule(wf, env, 1)
    lam, gamma = 50.0, 5.0
    cfg = SimConfig(ckpt_levels=(CkptLevel(lam, gamma),), resubmit=True,
                    busy_terminate=False)
    res = simulate(sched, _no_failure_trace(), cfg)
    base = simulate(sched, _no_failure_trace(), baselines.heft_sim_config())
    assert res.usage == pytest.approx(base.usage * (1 + gamma / lam), rel=1e-6)
    assert res.ckpt_overhead == pytest.approx(res.usage - base.usage, rel=1e-6)


def test_checkpoints_reduce_waste_on_failures():
    wf, env = _setup("ligo")
    sched = heft_schedule(wf, env, 1)
    waste_with, waste_without = [], []
    for seed in range(6):
        tr = sample_failure_trace("unstable", 20, horizon_s=200_000,
                                  seed=seed)
        with_ck = simulate(sched, tr, baselines.crch_ckpt_only_sim_config(
            lam=30.0, gamma=0.5))
        no_ck = simulate(sched, tr, SimConfig(ckpt_levels=(), resubmit=True,
                                              busy_terminate=False))
        if with_ck.completed and no_ck.completed:
            waste_with.append(with_ck.wastage)
            waste_without.append(no_ck.wastage)
    assert waste_with, "no comparable runs"
    assert np.mean(waste_with) <= np.mean(waste_without) + 1e-6


def test_replicate_all_usage_exceeds_crch_exceeds_heft():
    wf, env = _setup("montage")
    cfg = CRCHConfig()
    p = plan(wf, env, cfg, environment="normal")
    sh = baselines.heft_plan(wf, env)
    sr = baselines.replicate_all_plan(wf, env, 3)
    u = {"crch": [], "heft": [], "ra3": []}
    for seed in range(6):
        tr = sample_failure_trace("normal", 20, horizon_s=200_000, seed=seed)
        u["crch"].append(simulate(p.schedule, tr, sim_config(p, cfg)).usage)
        u["heft"].append(simulate(sh, tr, baselines.heft_sim_config()).usage)
        u["ra3"].append(simulate(sr, tr,
                                 baselines.replicate_all_sim_config()).usage)
    assert np.mean(u["ra3"]) > np.mean(u["crch"]) >= 0.95 * np.mean(u["heft"])


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(["montage", "sipht"]),
       envname=st.sampled_from(["stable", "normal", "unstable"]),
       seed=st.integers(0, 1000))
def test_property_simulation_invariants(kind, envname, seed):
    wf, env = _setup(kind, 100, seed=seed % 5)
    cfg = CRCHConfig()
    p = plan(wf, env, cfg, environment=envname)
    tr = sample_failure_trace(envname, 20, horizon_s=300_000, seed=seed)
    res = simulate(p.schedule, tr, sim_config(p, cfg))
    assert res.completed
    assert res.usage >= 0 and res.wastage >= 0
    assert res.wastage <= res.usage + 1e-6
    assert res.tet >= max(p.schedule.original(t).duration
                          for t in range(wf.n_tasks)) - 1e-6
    # completion order respects the DAG
    for child, parent, _ in wf.deps:
        assert res.task_complete[parent] <= res.task_complete[child] + 1e-6
