"""Tests for repro.obs: tracer/recorder semantics, metrics exposition,
dump-on-fault through a real chaos coordinator run, and the two invariants
the instrumented layers promise:

* a disabled tracer is a strict no-op (shared null span, no records);
* tracing is *passive* — a chaos-matrix cell replayed with the flight
  recorder attached produces a byte-identical result row.
"""
import collections
import json

import jax
import numpy as np
import pytest

from repro.chaos import (CKPT_CORRUPT, HOST_CRASH, NAN_POISON, SLOWDOWN,
                         ChaosEngine, FaultEvent, FaultTrace)
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.distributed.steps import make_train_step
from repro.ft import (CheckpointStore, DynamicInterval, TrainingCoordinator)
from repro.ft.crosspod import PodGradientExchange
from repro.models import lm
from repro.obs import (NULL_TRACER, FlightRecorder, MetricsRegistry, Tracer,
                       load_jsonl, profile_jit, setup, to_chrome)
from repro.obs.validate import validate_chrome, validate_dir, validate_events
from repro.optim import adamw_init
from repro.serve.metrics import ServeMetrics


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ------------------------------------------------------------- tracer ----

def test_null_tracer_is_shared_noop():
    assert not NULL_TRACER.enabled
    s1 = NULL_TRACER.span("x", step=1)
    s2 = NULL_TRACER.span("y")
    assert s1 is s2                       # one cached null object, no alloc
    with s1 as sp:
        assert sp.set(a=1) is sp
    NULL_TRACER.event("e")
    NULL_TRACER.fault("host_crash", step=3)
    NULL_TRACER.recovery("host_crash")
    # a tracer without a recorder is disabled even when asked to enable
    assert not Tracer(None, enabled=True).enabled


def test_span_nesting_parent_ids_and_error_attr():
    rec = FlightRecorder(64, clock=FakeClock())
    tr = Tracer(rec, clock=FakeClock())
    with tr.span("outer", step=1) as outer:
        with tr.span("inner"):
            tr.event("tick", n=2)
        outer.set(result="ok")
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    events = rec.snapshot()
    by_name = {e["name"]: e for e in events}
    assert by_name["tick"]["parent_id"] == by_name["inner"]["span_id"]
    assert by_name["inner"]["parent_id"] == by_name["outer"]["span_id"]
    assert by_name["outer"]["parent_id"] is None
    assert by_name["outer"]["attrs"] == {"step": 1, "result": "ok"}
    assert by_name["boom"]["attrs"]["error"] == "RuntimeError"
    # inner spans close first -> emitted first
    names = [e["name"] for e in events]
    assert names.index("inner") < names.index("outer")
    assert validate_events(events) == []


def test_complete_bypasses_stack():
    rec = FlightRecorder(16, clock=FakeClock())
    tr = Tracer(rec, clock=FakeClock())
    with tr.span("live"):
        tr.complete("offthread", 1.0, 5.0, track="ckpt-io", mode="async")
    off = [e for e in rec.snapshot() if e["name"] == "offthread"][0]
    assert off["parent_id"] is None and off["track"] == "ckpt-io"
    assert off["t0"] == 1.0 and off["t1"] == 5.0


# ----------------------------------------------------- recorder / ring ----

def test_ring_evicts_oldest_first():
    rec = FlightRecorder(4, clock=FakeClock())
    tr = Tracer(rec, clock=FakeClock())
    for i in range(10):
        tr.event(f"e{i}")
    assert len(rec) == 4
    assert [e["name"] for e in rec.snapshot()] == ["e6", "e7", "e8", "e9"]


def test_dump_on_fault_labels_cap_and_counters(tmp_path):
    clock = FakeClock()
    rec = FlightRecorder(32, out_dir=str(tmp_path), dump_on_fault=True,
                         max_dumps=3, clock=clock)
    tr = Tracer(rec, clock=clock)
    tr.fault("host_crash", step=1)
    tr.recovery("host_crash", restored_step=0)
    tr.fault("nan poison/..", step=2)     # label must be sanitized
    tr.fault("disk_full", step=3)         # over the cap: counted, not dumped
    assert [p.rsplit("/", 1)[-1] for p in rec.dumps] == [
        "0000_fault_host_crash.jsonl", "0001_recovery_host_crash.jsonl",
        "0002_fault_nan_poison_...jsonl"]
    assert rec.faults_seen == collections.Counter(
        {"host_crash": 1, "nan poison/..": 1, "disk_full": 1})
    assert rec.recoveries_seen == collections.Counter({"host_crash": 1})
    # the explicit final dump ignores the auto-dump cap
    final = rec.dump("run_end")
    assert final.endswith("0003_run_end.jsonl")
    assert [e["name"] for e in load_jsonl(final)] == [
        "fault.host_crash", "recover.host_crash", "fault.nan poison/..",
        "fault.disk_full"]
    problems, summary = validate_dir(str(tmp_path))
    assert problems == [] and summary["jsonl_files"] == 4


def test_window_filters_old_events():
    clock = FakeClock()
    rec = FlightRecorder(100, window_s=3.0, clock=clock)
    tr = Tracer(rec, clock=clock)
    for i in range(8):
        tr.event(f"e{i}")                 # event i lands at t = i + 1
    # snapshot() reads the clock once more; only the last ~3s survive
    assert [e["name"] for e in rec.snapshot()] == ["e5", "e6", "e7"]


def test_chrome_conversion_schema():
    rec = FlightRecorder(16, clock=FakeClock())
    tr = Tracer(rec, clock=FakeClock())
    with tr.span("work", step=4, skip=None):
        tr.event("mark")
    doc = to_chrome(rec.snapshot())
    assert validate_chrome(doc) == []
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    marks = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert len(spans) == 1 and len(marks) == 1
    assert spans[0]["dur"] > 0
    assert "skip" not in spans[0]["args"]     # None attrs are elided


# ------------------------------------------------------------ metrics ----

def test_counter_labels_and_value():
    reg = MetricsRegistry()
    c = reg.counter("drops_total", "drops", ("reason",))
    c.inc(reason="shed")
    c.inc(2.0, reason="hedge")
    assert c.value(reason="shed") == 1.0 and c.total() == 3.0
    assert reg.value("drops_total", reason="hedge") == 2.0
    assert reg.value("missing_metric") == 0.0
    with pytest.raises(ValueError):
        c.inc(wrong="label")
    # re-registration returns the same instrument; kind mismatch raises
    assert reg.counter("drops_total", "drops", ("reason",)) is c
    with pytest.raises(ValueError):
        reg.gauge("drops_total")


def test_prometheus_escaping_and_exposition():
    reg = MetricsRegistry()
    c = reg.counter("odd_total", 'help with \\ and\nnewline', ("path",))
    c.inc(path='a"b\\c\nd')
    text = reg.to_prometheus()
    assert '# HELP odd_total help with \\\\ and\\nnewline' in text
    assert 'odd_total{path="a\\"b\\\\c\\nd"} 1.0' in text
    assert "# TYPE odd_total counter" in text


def test_histogram_exposition_cumulative(tmp_path):
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", ("op",),
                      buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v, op="step")
    text = reg.to_prometheus()
    assert 'lat_seconds_bucket{op="step",le="0.1"} 1' in text
    assert 'lat_seconds_bucket{op="step",le="1.0"} 3' in text
    assert 'lat_seconds_bucket{op="step",le="+Inf"} 4' in text
    assert 'lat_seconds_count{op="step"} 4' in text
    assert h.sum(op="step") == pytest.approx(6.05)
    jpath, ppath = reg.write(str(tmp_path))
    dumped = json.load(open(jpath))
    assert dumped["lat_seconds"]["series"]["op=step"]["count"] == 4


def test_serve_metrics_shim_maps_to_registry():
    reg = MetricsRegistry()
    m = ServeMetrics(registry=reg)
    m.shed += 1
    m.rejected_on_arrival += 2
    m.past_first_token_drops += 1
    m.failures += 1
    m.prefill_tokens += 64
    assert m.shed == 1 and m.rejected_on_arrival == 2
    assert reg.value("serve_drops_total", reason="shed") == 1.0
    assert reg.value("serve_drops_total",
                     reason="rejected_on_arrival") == 2.0
    assert reg.value("serve_drops_total", reason="past_first_token") == 1.0
    assert reg.value("serve_events_total", kind="worker_failure") == 1.0
    assert reg.value("serve_tokens_total", kind="prefill") == 64.0
    s = m.summary(10)
    assert s["shed"] == 1 and s["past_first_drops"] == 1


# ------------------------------------------------------------ profile ----

def test_profile_jit_records_compile_then_steady_state():
    reg = MetricsRegistry()
    fn = jax.jit(lambda x: x * 2.0)
    prof = profile_jit(fn, name="double", registry=reg, clock=FakeClock())
    x = np.ones(4, np.float32)
    for _ in range(4):
        prof(x)
    rep = prof.report()
    assert rep["compile_s"] is not None and rep["calls"] == 3
    assert reg.value("profile_compile_seconds", step="double") > 0
    assert reg.value("profile_step_seconds", step="double") == 3.0
    cost = prof.capture_cost(x)
    assert prof.stats.flops is not None and "flops" in cost
    assert prof.report()["achieved_flops_per_s"] is not None


# ----------------------------------------- chaos run -> dumps on fault ----

@pytest.fixture(scope="module")
def train_setup():
    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, q_chunk=16, xent_chunk=16))
    data_cfg = DataConfig(global_batch=4, seq_len=32)
    return cfg, params, opt, step, data_cfg


def run_chaos_coordinator(train_setup, ckpt_dir, *, tracer=None,
                          registry=None, n_steps=18):
    cfg, params, opt, step, data_cfg = train_setup
    trace = FaultTrace(events=[
        FaultEvent(step=3, kind=SLOWDOWN, targets=(0,), duration=2),
        FaultEvent(step=6, kind=NAN_POISON),
        FaultEvent(step=9, kind=CKPT_CORRUPT, targets=(0,)),
        FaultEvent(step=11, kind=HOST_CRASH, targets=(0,), duration=2),
    ])
    coord = TrainingCoordinator(
        train_step=step, params=params, opt_state=opt,
        pipeline=SyntheticTokenPipeline(data_cfg, cfg),
        store=CheckpointStore(ckpt_dir, tracer=tracer),
        interval=DynamicInterval(gamma_s=1.0, lam_min=2.0, lam_max=2.0),
        chaos=ChaosEngine(trace, tracer=tracer),
        tracer=tracer, registry=registry)
    return coord.run(n_steps)


def test_coordinator_dumps_on_three_fault_classes(train_setup, tmp_path):
    ctx = setup(str(tmp_path / "trace"), dump_on_fault=True)
    report = run_chaos_coordinator(train_setup, str(tmp_path / "ckpt"),
                                   tracer=ctx.tracer, registry=ctx.registry)
    assert report.steps_completed == 18
    assert ctx.finish() is not None
    assert set(ctx.recorder.faults_seen) >= {
        SLOWDOWN, NAN_POISON, CKPT_CORRUPT, HOST_CRASH}
    dump_names = [p.rsplit("/", 1)[-1] for p in ctx.recorder.dumps]
    for kind in (SLOWDOWN, NAN_POISON, CKPT_CORRUPT, HOST_CRASH):
        assert any(f"fault_{kind}" in n for n in dump_names), kind
    problems, summary = validate_dir(
        str(tmp_path / "trace"),
        require_spans=[f"fault.{HOST_CRASH}", f"recover.{HOST_CRASH}",
                       f"recover.{NAN_POISON}", "ckpt.save",
                       "ckpt.restore"])
    assert problems == []
    # the registry absorbed the coordinator's counters
    assert ctx.registry.value("train_events_total", kind="failure") >= 1
    assert ctx.registry.value("train_events_total",
                              kind="nan_rollback") >= 1
    assert ctx.registry.value("train_checkpoints_total",
                              mode="sync") + ctx.registry.value(
        "train_checkpoints_total", mode="async") == report.checkpoints


def test_traced_run_is_bit_identical_to_untraced(train_setup, tmp_path):
    plain = run_chaos_coordinator(train_setup, str(tmp_path / "a"))
    ctx = setup(str(tmp_path / "trace"), dump_on_fault=True)
    traced = run_chaos_coordinator(train_setup, str(tmp_path / "b"),
                                   tracer=ctx.tracer,
                                   registry=ctx.registry)
    assert plain.losses == traced.losses
    assert plain.failures == traced.failures
    assert plain.nan_rollbacks == traced.nan_rollbacks
    assert plain.checkpoints == traced.checkpoints


def test_chaos_matrix_serve_cell_row_identical_traced(tmp_path):
    chaos_matrix = pytest.importorskip(
        "benchmarks.chaos_matrix",
        reason="benchmarks/ not importable from this rootdir")
    cfg = get_config("olmo-1b", tiny=True)
    params = lm.init_params(jax.random.key(1), cfg)
    trace = chaos_matrix.cell_trace("unstable", "serve", HOST_CRASH,
                                    horizon=120, n_targets=4, seed=5)
    kw = dict(n_requests=4, max_steps=400, seed=5)
    # ChaosEngine never mutates the trace, so the same one replays twice
    row_plain = chaos_matrix.run_serve_cell(cfg, params, trace, **kw)
    ctx = setup(str(tmp_path / "trace"), dump_on_fault=True)
    row_traced = chaos_matrix.run_serve_cell(cfg, params, trace,
                                             tracer=ctx.tracer, **kw)
    assert (json.dumps(row_plain, sort_keys=True)
            == json.dumps(row_traced, sort_keys=True))
    assert ctx.recorder.faults_seen


# ------------------------------------------------- fingerprint gating ----

def test_exchange_round_skips_fingerprint_on_request():
    ex = PodGradientExchange(2)
    grads = {"w": np.ones(8, np.float32)}
    with_fp = ex.round([grads, grads])
    assert with_fp.fingerprint
    without = ex.round([grads, grads], with_fingerprint=False)
    assert without.fingerprint is None
