"""Edge-case regression tests for the core simulator utilities."""
import math
import warnings

import pytest

from repro.core import CkptLevel, SimConfig
from repro.core.metrics import aggregate


def test_ckpt_level_rejects_nonpositive_lam():
    with pytest.raises(ValueError, match="lam"):
        CkptLevel(lam=0.0, gamma=1.0)
    with pytest.raises(ValueError, match="lam"):
        CkptLevel(lam=-5.0, gamma=1.0)
    with pytest.raises(ValueError, match="lam"):
        CkptLevel(lam=float("nan"), gamma=1.0)


def test_ckpt_level_rejects_negative_gamma():
    with pytest.raises(ValueError, match="gamma"):
        CkptLevel(lam=60.0, gamma=-1.0)


def test_overhead_rate_well_defined():
    cfg = SimConfig(ckpt_levels=(CkptLevel(60.0, 3.0),
                                 CkptLevel(600.0, 30.0)))
    assert cfg.overhead_rate() == pytest.approx(3.0 / 60.0 + 30.0 / 600.0)
    assert SimConfig().overhead_rate() == 0.0


def test_aggregate_empty_runs_is_explicit():
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        out = aggregate([])
    assert out["n_runs"] == 0.0
    assert out["success_rate"] == 0.0
    assert math.isnan(out["usage"])
    assert math.isnan(out["tet"])
