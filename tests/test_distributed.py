"""Distributed-layer unit tests: sharding specs, rules, step builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import params as pshard
from repro.distributed.sharding import (DEFAULT_RULES, constrain,
                                        logical_to_spec, use_rules)
from repro.distributed.steps import make_train_step
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.models import lm
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh()


def _abstract(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(lambda: lm.init_params(jax.random.key(0), cfg))


def test_param_specs_cover_all_leaves_and_divide(mesh):
    for arch in ("deepseek_coder_33b", "phi35_moe_42b", "recurrentgemma_2b",
                 "rwkv6_3b", "whisper_small"):
        cfg, ab = _abstract(arch)
        specs = pshard.param_specs(ab, mesh)
        flat_p = jax.tree.leaves(ab)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= leaf.ndim
            for dim, part in zip(leaf.shape, tuple(spec)):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % size == 0, (arch, leaf.shape, spec)


def test_zero1_strips_data_axis(mesh):
    cfg, ab = _abstract("olmo_1b")
    full = jax.tree.leaves(pshard.param_specs(ab, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    z1 = jax.tree.leaves(pshard.param_specs(ab, mesh, zero1=True),
                         is_leaf=lambda x: isinstance(x, P))
    assert any("data" in tuple(s) for s in full)
    assert not any("data" in tuple(s) for s in z1)
    # model-axis TP is preserved
    assert any("model" in tuple(s) for s in z1)


def test_opt_specs_keep_master_fully_sharded(mesh):
    cfg, ab = _abstract("olmo_1b")
    opt = jax.eval_shape(lambda p: adamw_init(p, master=True), ab)
    ospec = pshard.opt_state_specs(opt, ab, mesh, zero1=True)
    assert "master" in ospec
    flat = jax.tree.leaves(ospec["master"],
                           is_leaf=lambda x: isinstance(x, P))
    assert any("data" in tuple(s) for s in flat)


def test_cache_specs_seq_sharded(mesh):
    cfg = get_config("deepseek_coder_33b")
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 32768))
    specs = pshard.cache_specs(cache, cfg, mesh)
    k_spec = specs["k"]
    assert tuple(k_spec) == (None, "data", "model", None, None)


class _ProdMeshStub:
    """Production-mesh extents without needing 256 real devices."""
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def test_cache_specs_fall_back_when_indivisible():
    cfg = get_config("rwkv6_3b")
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 1024))
    specs = pshard.cache_specs(cache, cfg, _ProdMeshStub())
    # batch=1 cannot shard over data=16 -> replicated; heads 40 over
    # model=16 indivisible -> replicated
    assert tuple(specs["S"])[1] is None
    assert tuple(specs["S"])[2] is None
    # divisible dims keep their axes (x_tm: (L, B, D) with D=2560)
    assert tuple(specs["x_tm"])[2] == "model"


def test_param_specs_fall_back_for_indivisible_vocab():
    # granite-moe vocab 49155 does not divide model=16 -> replicated
    cfg, ab = _abstract("granite_moe_1b")
    specs = pshard.param_specs(ab, _ProdMeshStub())
    embed_spec = tuple(specs["embed"])
    assert embed_spec[0] is None           # vocab 49155 % 16 != 0
    assert embed_spec[1] == "data"         # d_model 1024 divides


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, ("batch", "embed"))
    assert y is x


def test_constrain_divisibility_guard(mesh):
    with use_rules(mesh):
        # 3 does not divide any axis of the debug mesh -> still legal
        x = jnp.ones((3, 5))
        y = constrain(x, ("batch", "mlp"))
        assert y.shape == x.shape


def test_logical_to_spec_respects_rules(mesh):
    with use_rules(mesh, {"seq_resid": None}):
        spec = logical_to_spec(("batch", "seq_resid", "embed"))
        assert tuple(spec)[1] is None
    with use_rules(mesh):
        spec = logical_to_spec(("batch", "seq_resid", "embed"))
        assert tuple(spec)[1] == "model"


def test_all_40_cells_are_defined():
    """The assigned matrix: 10 archs x 4 shapes, with documented skips."""
    from repro.configs import ARCHS
    n_ok = n_skip = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            if ok:
                specs = input_specs(cfg, shape)
                assert specs, (arch, shape.name)
                n_ok += 1
            else:
                assert "attention" in why
                n_skip += 1
    assert n_ok == 32 and n_skip == 8


def test_train_step_with_grad_shardings_runs(mesh):
    cfg = get_config("olmo_1b", tiny=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params, master=True)
    ab = jax.eval_shape(lambda: params)
    gsh = pshard.param_shardings(ab, mesh)
    step = jax.jit(make_train_step(cfg, accum_steps=2, q_chunk=16,
                                   xent_chunk=16, grad_shardings=gsh))
    from repro.launch.shapes import make_batch
    batch = make_batch(cfg, batch=4, seq=32)
    with use_rules(mesh):
        p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert o2["step"] == 1
    # master copy tracks the bf16/fp32 params
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(o2["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
