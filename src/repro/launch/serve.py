"""Production serving launcher: prefill + batched greedy decode.

On TPU this runs under the production mesh with the ZeRO-1/TP weight layout
and the sequence-sharded KV cache; on CPU, ``--tiny`` validates the same
code end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tiny \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import params as pshard
from repro.distributed.sharding import use_rules
from repro.distributed.steps import make_prefill_step, make_serve_step
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.shapes import make_batch
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mesh", choices=("debug", "single", "multi"),
                    default="debug")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    mesh = (make_debug_mesh() if args.mesh == "debug" else
            make_production_mesh(multi_pod=(args.mesh == "multi")))
    cache_len = args.prompt_len + args.new_tokens + (cfg.n_image_tokens or 0)

    with use_rules(mesh):
        params = lm.init_params(jax.random.key(args.seed), cfg)
        abstract = jax.eval_shape(lambda: params)
        psh = pshard.param_shardings(abstract, mesh, zero1=True)
        params = jax.device_put(params, psh)
        prefill = jax.jit(make_prefill_step(
            cfg, cache_len, q_chunk=min(1024, args.prompt_len)))
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

        batch = make_batch(cfg, batch=args.batch, seq=args.prompt_len,
                           seed=args.seed)
        prompts = {k: v for k, v in batch.items()
                   if k in ("tokens", "frames", "image_embeds")}
        t0 = time.time()
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0

        pos0 = args.prompt_len + (cfg.n_image_tokens or 0)
        out = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            tok, logits, cache = serve(params, cache, tok,
                                       jnp.int32(pos0 + i))
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tok_s = args.batch * (args.new_tokens - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} ({cfg.param_count() / 1e6:.0f}M params) "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens} mesh={args.mesh}")
    print(f"prefill {t_prefill * 1e3:.0f} ms | decode "
          f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/token "
          f"({tok_s:.1f} tok/s aggregate)")
    assert np.isfinite(np.asarray(logits)).all()
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
