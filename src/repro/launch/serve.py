"""Production serving launcher: fault-tolerant continuous batching.

Requests are admitted through ``repro.serve``: freed decode slots prefill
new requests while live requests keep decoding; replication follows the
selected policy (``none`` / ``all-k`` / ``crch``) and failed workers resume
requests from their last decode snapshot.  Every model family — dense, MoE,
RWKV, RG-LRU hybrid, encoder-decoder, multimodal — runs through the
continuous engine; ``--static`` explicitly selects the legacy one-shot
static batch (a baseline, not a fallback), and ``--verify-static`` checks
the engine's tokens token-for-token against the batch=1 static reference.

On TPU this runs under the production mesh with the ZeRO-1/TP weight layout
and the sequence-sharded KV cache; on CPU, ``--tiny`` validates the same
code end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --tiny \
        --requests 8 --prompt-len 32 --new-tokens 16 --policy crch \
        --env normal
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.chaos import SERVE_KINDS, ChaosEngine, FaultTrace, sample_trace
from repro.configs import get_config
from repro.distributed import params as pshard
from repro.distributed.sharding import use_rules
from repro.distributed.steps import make_prefill_step, make_serve_step
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.shapes import make_batch
from repro.models import lm
from repro.serve import (EngineConfig, Request, ServeEngine, ServeMetrics,
                         WorkerPool, crch_policy, engine_supported,
                         greedy_reference, prompt_bucket, uniform_policy)


def make_chaos(args, *, kinds, n_targets: int, horizon: int, tracer=None):
    """Build a ChaosEngine from the --chaos* flags (None when disabled).

    ``--chaos-trace`` replays a recorded trace verbatim (bit-identical run);
    otherwise ``--chaos PROFILE`` samples a fresh trace from the profile's
    Section 4.1 distributions, optionally recorded with ``--chaos-record``.
    An obs tracer annotates every applied fault (``fault.<kind>``) and arms
    the flight recorder's dump-on-fault trigger.
    """
    if args.chaos_trace:
        trace = FaultTrace.load(args.chaos_trace)
    elif args.chaos != "none":
        trace = sample_trace(args.chaos, horizon=horizon,
                             n_targets=n_targets, seed=args.chaos_seed,
                             kinds=kinds)
    else:
        return None
    if args.chaos_record:
        trace.save(args.chaos_record)
    print(f"chaos: {len(trace)} events over {sorted(trace.kinds())} "
          f"(meta={trace.meta})")
    return ChaosEngine(trace, tracer=tracer)


def add_trace_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--trace-dir", default="",
                    help="enable the repro.obs flight recorder; JSONL + "
                         "Chrome trace dumps and metrics land here")
    ap.add_argument("--trace-dump-on-fault", action="store_true",
                    help="dump the recorder window on every fault injected "
                         "and every recovery path taken")
    ap.add_argument("--trace-capacity", type=int, default=8192,
                    help="flight-recorder ring capacity (events)")
    ap.add_argument("--trace-window-s", type=float, default=0.0,
                    help="dump only the last N seconds of the ring "
                         "(0 = the whole ring)")


def make_obs(args) -> obs.ObsContext:
    """Build the run's ObsContext from the --trace* flags.  Without
    ``--trace-dir`` this is the NULL tracer + a detached registry."""
    return obs.setup(args.trace_dir or None,
                     dump_on_fault=args.trace_dump_on_fault,
                     capacity=args.trace_capacity,
                     window_s=args.trace_window_s or None)


def add_chaos_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--chaos", choices=("none", "stable", "normal",
                                        "unstable"), default="none",
                    help="sample a multi-fault chaos trace from this profile")
    ap.add_argument("--chaos-trace", default="",
                    help="replay a recorded fault trace (JSON) verbatim")
    ap.add_argument("--chaos-record", default="",
                    help="record the active fault trace to this path")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-horizon", type=int, default=0,
                    help="trace horizon in steps (0 = derive from the run)")
    ap.add_argument("--chaos-assert", action="store_true",
                    help="CI smoke: require survival — completions with "
                         "nonzero restores/resubmissions and zero "
                         "past-first-token drops")


def _sharded_params(cfg, mesh, seed: int):
    params = lm.init_params(jax.random.key(seed), cfg)
    abstract = jax.eval_shape(lambda: params)
    psh = pshard.param_shardings(abstract, mesh, zero1=True)
    return jax.device_put(params, psh)


def _make_requests(cfg, n: int, prompt_len: int, new_tokens: int,
                   seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(prompt_len // 2, 4), prompt_len + 1))
        newt = new_tokens if i % 3 else new_tokens * 2
        frames = (rng.normal(size=(cfg.n_frames, cfg.d_model))
                  .astype(np.float32) if cfg.is_encdec else None)
        embeds = (rng.normal(size=(cfg.n_image_tokens, cfg.d_model))
                  .astype(np.float32) if cfg.n_image_tokens else None)
        reqs.append(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, plen,
                                       dtype=np.int64).astype(np.int32),
            max_new_tokens=newt, arrival=0,
            deadline=16 * (plen + newt),
            frames=frames, image_embeds=embeds))
    return reqs


def continuous_main(cfg, mesh, args) -> None:
    reqs = _make_requests(cfg, args.requests, args.prompt_len,
                          args.new_tokens, args.seed)
    offset = cfg.n_image_tokens or 0
    cache_len = max(offset + prompt_bucket(r.prompt_len) + r.max_new_tokens
                    for r in reqs)
    if cfg.rglru and cfg.window:
        cache_len = max(cache_len, cfg.window)
    if args.policy == "crch":
        policy = crch_policy(reqs)
    elif args.policy == "all":
        policy = uniform_policy(args.max_rep)
    else:
        policy = uniform_policy(1)
    pool = WorkerPool(args.workers, args.slots_per_worker,
                      environment=(args.env if args.env != "none" else None),
                      seed=args.seed)
    horizon = args.chaos_horizon or min(
        args.max_steps, 8 * max(r.max_new_tokens for r in reqs))
    ctx = make_obs(args)
    chaos = make_chaos(args, kinds=SERVE_KINDS, n_targets=args.workers,
                       horizon=horizon, tracer=ctx.tracer)
    with use_rules(mesh):
        params = _sharded_params(cfg, mesh, args.seed)
        engine = ServeEngine(
            cfg, EngineConfig(cache_len=cache_len, q_chunk=64,
                              max_queue_depth=args.max_queue_depth or None),
            pool=pool, policy=policy, params=params,
            metrics=ServeMetrics(registry=ctx.registry), chaos=chaos,
            tracer=ctx.tracer)
        for r in reqs:
            engine.submit(r)
        t0 = time.time()
        metrics = engine.run(max_steps=args.max_steps)
        wall = time.time() - t0
    s = metrics.summary(engine.step_no)
    tok_s = metrics.decode_tokens / max(wall, 1e-9)
    print(f"arch={cfg.name} ({cfg.param_count() / 1e6:.0f}M params) "
          f"requests={args.requests} slots={pool.n_slots} "
          f"policy={policy.name} env={args.env} mesh={args.mesh}")
    print(f"{engine.step_no} engine steps in {wall:.2f}s "
          f"({tok_s:.1f} tok/s aggregate) | completed "
          f"{int(s['completed'])}/{args.requests} "
          f"(in-deadline {int(s['in_deadline'])}) | "
          f"p50/p99 latency {s['p50_latency']:.0f}/{s['p99_latency']:.0f} "
          f"steps")
    print(f"usage {s['usage_tokens']:.0f} tok | wasted "
          f"{s['wasted_tokens']:.0f} tok ({100 * s['wastage_frac']:.1f}%) | "
          f"failures {int(s['failures'])} resubmissions "
          f"{int(s['resubmissions'])} snapshot-restores "
          f"{int(s['restores'])} rejected-on-arrival "
          f"{int(s['rejected_on_arrival'])}")
    if chaos is not None:
        print(f"chaos applied: {dict(chaos.applied_by_kind)} | shed "
              f"{int(s['shed'])} hedge-drops {int(s['hedge_drops'])} "
              f"snapshot-verify-fails {int(s['snapshot_restore_failures'])} "
              f"past-first-token drops {int(s['past_first_drops'])}")
    done = sorted(engine.completed)
    assert done, "no requests completed"
    print("sample:", engine.completed[done[0]][:12])
    if ctx.finish() is not None:
        rec = ctx.recorder
        print(f"trace: {len(rec.dumps)} dump(s) + metrics under "
              f"{args.trace_dir} (faults seen "
              f"{dict(rec.faults_seen)}, recoveries "
              f"{dict(rec.recoveries_seen)})")
    if args.chaos_assert:
        assert chaos is not None, "--chaos-assert needs an active chaos run"
        assert chaos.applied, "chaos trace fired no events"
        assert s["completed"] > 0, "no requests survived the chaos run"
        recoveries = int(s["restores"]) + int(s["resubmissions"])
        assert recoveries > 0, (
            "chaos run exercised no recovery path "
            f"(restores+resubmissions == 0, applied "
            f"{dict(chaos.applied_by_kind)})")
        assert s["past_first_drops"] == 0, (
            f"{int(s['past_first_drops'])} request(s) dropped past their "
            f"first token — degraded mode must never shed live work")
        print(f"chaos-assert OK: {int(s['completed'])} completed, "
              f"{recoveries} recoveries, 0 past-first-token drops")
    if args.verify_static:
        with use_rules(mesh):
            ref = greedy_reference(params, cfg, reqs, cache_len, q_chunk=64)
        mismatched = [r.rid for r in reqs
                      if engine.output(r.rid) != ref[r.rid]]
        print(f"parity vs static reference: "
              f"{len(reqs) - len(mismatched)}/{len(reqs)} token-exact"
              + (f" (MISMATCH rids {mismatched})" if mismatched else ""))
        assert not mismatched, f"token parity failed for rids {mismatched}"


def static_main(cfg, mesh, args) -> None:
    """Legacy one-shot static batch (non-KV-cache-friendly families)."""
    cache_len = args.prompt_len + args.new_tokens + (cfg.n_image_tokens or 0)
    with use_rules(mesh):
        params = _sharded_params(cfg, mesh, args.seed)
        prefill = jax.jit(make_prefill_step(
            cfg, cache_len, q_chunk=min(1024, args.prompt_len)))
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

        batch = make_batch(cfg, batch=args.requests, seq=args.prompt_len,
                           seed=args.seed)
        prompts = {k: v for k, v in batch.items()
                   if k in ("tokens", "frames", "image_embeds")}
        t0 = time.time()
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0

        pos0 = args.prompt_len + (cfg.n_image_tokens or 0)
        out = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            tok, logits, cache = serve(params, cache, tok,
                                       jnp.int32(pos0 + i))
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tok_s = args.requests * (args.new_tokens - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} ({cfg.param_count() / 1e6:.0f}M params) "
          f"batch={args.requests} prompt={args.prompt_len} "
          f"new={args.new_tokens} mesh={args.mesh} [static]")
    print(f"prefill {t_prefill * 1e3:.0f} ms | decode "
          f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/token "
          f"({tok_s:.1f} tok/s aggregate)")
    assert np.isfinite(np.asarray(logits)).all()
    print("sample:", gen[0][:12].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", "--batch", type=int, default=4,
                    dest="requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--slots-per-worker", type=int, default=2)
    ap.add_argument("--policy", choices=("none", "all", "crch"),
                    default="crch")
    ap.add_argument("--max-rep", type=int, default=3)
    ap.add_argument("--max-queue-depth", type=int, default=0,
                    help="queue-length-priced admission: reject fresh "
                         "arrivals with a retry_after hint once the queue "
                         "holds this many work items (0 = unbounded)")
    ap.add_argument("--env", choices=("none", "stable", "normal", "unstable"),
                    default="none")
    ap.add_argument("--max-steps", type=int, default=20_000)
    ap.add_argument("--static", action="store_true",
                    help="run the legacy one-shot static batch baseline")
    ap.add_argument("--verify-static", action="store_true",
                    help="check engine tokens against the batch=1 static "
                         "reference, token-for-token")
    ap.add_argument("--mesh", choices=("debug", "single", "multi"),
                    default="debug")
    ap.add_argument("--seed", type=int, default=0)
    add_chaos_args(ap)
    add_trace_args(ap)
    args = ap.parse_args()
    if args.static and (args.chaos != "none" or args.chaos_trace):
        raise SystemExit("--static has no fault tolerance to chaos-test; "
                         "use the continuous engine")

    cfg = get_config(args.arch, tiny=args.tiny)
    mesh = (make_debug_mesh() if args.mesh == "debug" else
            make_production_mesh(multi_pod=(args.mesh == "multi")))
    supported, why = engine_supported(cfg)
    if not supported:
        raise SystemExit(f"{args.arch}: {why}")
    if args.static:
        static_main(cfg, mesh, args)
    else:
        continuous_main(cfg, mesh, args)


if __name__ == "__main__":
    main()
