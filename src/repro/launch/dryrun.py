import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the multi-pod dry-run needs 512
# placeholder host devices to build the production meshes.

import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import normalize_cost_analysis   # noqa: E402
from repro.configs import ARCHS, get_config              # noqa: E402
from repro.distributed import params as pshard           # noqa: E402
from repro.distributed.sharding import use_rules         # noqa: E402
from repro.distributed.steps import (make_prefill_step,  # noqa: E402
                                     make_serve_step, make_train_step)
from repro.launch import shapes as shp                   # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.optim import adamw_init                       # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "out", "dryrun")

# grad-accumulation per architecture (train_4k): bounds activation memory.
# Values tuned by the section-Perf iterations (EXPERIMENTS.md): the
# per-device remat carry is (mb/16, S, d_model) bf16 per layer, so accum
# rises with L * d_model until temp fits the 16 GiB v5e HBM.
ACCUM = {
    "command_r_plus_104b": 8, "deepseek_coder_33b": 8, "granite_20b": 4,
    "phi35_moe_42b": 8, "llava_next_mistral_7b": 2,
    "rwkv6_3b": 2, "recurrentgemma_2b": 2, "olmo_1b": 1,
    "granite_moe_1b": 1, "whisper_small": 1,
}

# ZeRO-1 (bf16 params replicated over `data`, fp32 master+moments sharded):
# kills the per-layer per-microbatch FSDP weight all-gathers that dominated
# the baseline collective term (EXPERIMENTS.md section Perf, iteration 4).
# command-r-plus's bf16 weights alone are 13 GiB per model shard, which
# cannot be replicated over the data axis on 16 GiB v5e -> it stays FSDP
# (at 104B on 256 chips the production answer is pipeline parallelism).
ZERO1 = {
    "deepseek_coder_33b": True, "command_r_plus_104b": False,
    "olmo_1b": True, "granite_20b": True, "phi35_moe_42b": True,
    "granite_moe_1b": True, "recurrentgemma_2b": True,
    "llava_next_mistral_7b": True, "rwkv6_3b": True, "whisper_small": True,
}

# sequence-parallel residual stream: a memory/collective trade-off (an
# all-gather + reduce-scatter pair per layer per microbatch buys a
# model-axis-fold reduction of the remat carries).  Only the architectures
# whose activations would otherwise exceed HBM keep it on (section Perf
# iteration 5): small/narrow models are cheaper without it.
SEQPAR = {
    "command_r_plus_104b": True, "deepseek_coder_33b": True,
    "phi35_moe_42b": True, "llava_next_mistral_7b": True,
    "granite_20b": True, "granite_moe_1b": True,
    "recurrentgemma_2b": True, "rwkv6_3b": True,
    # measured cheaper without it (activations already fit):
    "olmo_1b": False, "whisper_small": False,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective op, parsed from the post-SPMD HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            m = re.search(r"=\s+(\S.*?)\s+([a-z0-9-]+)\(", ls)
            if not m:
                continue
            type_str, op = m.group(1), m.group(2)
            base = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base is None:
                continue
            out[base] += _shape_bytes(type_str)
            counts[base] += 1
    return {"bytes": out, "counts": counts}


def _flatten_cost(cost) -> dict:
    return {k: float(v)
            for k, v in normalize_cost_analysis(cost).items()
            if isinstance(v, (int, float))}


def _mem_analysis(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _specs_to_shardings(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, *, q_chunk: int = 1024):
    """Returns (jitted_fn, arg_sds) for one (arch x shape) cell."""
    import dataclasses

    cfg = dataclasses.replace(get_config(arch), param_dtype="bfloat16")
    shape = shp.SHAPES[shape_name]
    ok, why = shp.cell_supported(cfg, shape)
    if not ok:
        return None, why
    zero1 = ZERO1.get(arch, True)

    abstract_params = jax.eval_shape(
        lambda: lm.init_params(jax.random.key(0), cfg))
    pspec = pshard.param_specs(abstract_params, mesh, zero1=zero1)
    psh = _specs_to_shardings(pspec, mesh)

    if shape.kind == "train":
        accum = ACCUM.get(arch, 1)
        abstract_opt = jax.eval_shape(
            lambda p: adamw_init(p, master=True), abstract_params)
        ospec = pshard.opt_state_specs(abstract_opt, abstract_params, mesh,
                                       zero1=zero1)
        osh = _specs_to_shardings(ospec, mesh)
        grad_sh = _specs_to_shardings(
            pshard.param_specs(abstract_params, mesh), mesh) if zero1 \
            else None
        step = make_train_step(cfg, accum_steps=accum, q_chunk=q_chunk,
                               grad_shardings=grad_sh)
        batch_sds = shp.input_specs(cfg, shape)
        bspec = pshard.batch_specs(batch_sds, mesh)
        bsh = _specs_to_shardings(bspec, mesh)
        # params/opt are consumed and re-emitted every step: donate them
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        args = (abstract_params, abstract_opt, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, cache_len=shape.seq_len,
                                 q_chunk=q_chunk)
        batch_sds = shp.input_specs(cfg, shape)
        bspec = pshard.batch_specs(batch_sds, mesh)
        bsh = _specs_to_shardings(bspec, mesh)
        abstract_cache = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                  jnp.bfloat16))
        cspec = pshard.cache_specs(abstract_cache, cfg, mesh)
        csh = _specs_to_shardings(cspec, mesh)
        jitted = jax.jit(step, in_shardings=(psh, bsh),
                         out_shardings=(None, csh))
        args = (abstract_params, batch_sds)
    else:  # decode
        step = make_serve_step(cfg)
        specs = shp.input_specs(cfg, shape)
        cspec = pshard.cache_specs(specs["cache"], cfg, mesh)
        csh = _specs_to_shardings(cspec, mesh)
        tok_sh = NamedSharding(mesh, pshard.batch_specs(
            specs["tokens"], mesh))
        pos_sh = NamedSharding(mesh, P())
        # donate the cache: serving updates it in place (halves cache HBM)
        jitted = jax.jit(step, in_shardings=(psh, csh, tok_sh, pos_sh),
                         out_shardings=(tok_sh, None, csh),
                         donate_argnums=(1,))
        args = (abstract_params, specs["cache"], specs["tokens"],
                specs["pos"])
    return (jitted, args), None


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             save_hlo: bool = True) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": list(dict(zip(mesh.axis_names,
                                       mesh.devices.shape)).items())}
    rules = {} if SEQPAR.get(arch, True) else {"seq_resid": None}
    with use_rules(mesh, rules):
        built, why = build_cell(arch, shape_name, mesh)
        if built is None:
            row.update(status="skipped", reason=why)
            return row
        jitted, args = built
        lowered = jitted.lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
    hlo = compiled.as_text()
    row.update(
        status="ok",
        lower_s=round(t_lower - t0, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory=_mem_analysis(compiled),
        cost=_flatten_cost(compiled.cost_analysis()),
        collectives=collective_bytes(hlo),
        hlo_lines=hlo.count("\n"),
    )
    if save_hlo:
        os.makedirs(OUT_DIR, exist_ok=True)
        with gzip.open(os.path.join(
                OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"),
                "wt") as f:
            f.write(hlo)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ARCHS))
    ap.add_argument("--shape", nargs="*", default=list(shp.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have results")
    ap.add_argument("--no-save-hlo", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    failures = 0
    for arch in args.arch:
        for shape_name in args.shape:
            for mesh_kind in meshes:
                path = os.path.join(
                    OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {arch} {shape_name} {mesh_kind}")
                    continue
                try:
                    row = run_cell(arch, shape_name, mesh_kind,
                                   save_hlo=not args.no_save_hlo)
                except Exception as e:
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                mem = row.get("memory", {})
                cost = row.get("cost", {})
                print(f"[{row['status']:7s}] {arch} {shape_name} {mesh_kind} "
                      f"lower={row.get('lower_s', 0)}s "
                      f"compile={row.get('compile_s', 0)}s "
                      f"args={mem.get('argument_size_in_bytes', 0) / 2**30:.2f}GiB "
                      f"temp={mem.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB "
                      f"flops={cost.get('flops', 0):.3g}",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
