"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; real launches get the same topology from the TPU runtime.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod slice; 2x16x16 = two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "for the dry-run")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh(shape, axes, devices=jax.devices()[:1])
