"""Assigned input-shape sets and ShapeDtypeStruct input specs.

Every (architecture x shape) cell is defined here; ``input_specs`` returns
weak-type-correct ``jax.ShapeDtypeStruct`` stand-ins (no allocation) for the
dry-run, and ``make_batch`` materializes small real batches for smoke tests.

``decode_*`` / ``long_*`` shapes lower ``serve_step`` (one new token against
a seq_len KV cache); ``long_500k`` requires sub-quadratic attention and runs
only for the hybrid/SSM architectures (full-attention archs record a
documented skip); encoder-only archs would skip decode shapes (all ten
assigned archs have a decode path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import lm

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# long_500k only for sub-quadratic sequence mixing (see DESIGN.md)
SUBQUADRATIC = {"hybrid", "ssm"}


def cell_supported(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, "full quadratic attention: 500k decode infeasible"
    return True, ""


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - cfg.n_image_tokens if cfg.n_image_tokens else seq_len


def train_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    b, s = shape.global_batch, _text_len(cfg, shape.seq_len)
    spec = {
        "tokens": SDS((b, s), jnp.int32),
        "targets": SDS((b, s), jnp.int32),
        "loss_mask": SDS((b, s), jnp.float32),
    }
    if cfg.is_encdec:
        spec["frames"] = SDS((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_image_tokens:
        spec["image_embeds"] = SDS((b, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    return spec


def prefill_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    b, s = shape.global_batch, _text_len(cfg, shape.seq_len)
    spec = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.is_encdec:
        spec["frames"] = SDS((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_image_tokens:
        spec["image_embeds"] = SDS((b, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    return spec


def decode_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    b = shape.global_batch
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, b, shape.seq_len, dtype=jnp.bfloat16))
    return {
        "cache": cache,
        "tokens": SDS((b, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


# ---------------------------------------------------------------------------
# real (small) batches for smoke tests / examples
# ---------------------------------------------------------------------------

def make_batch(cfg: ModelConfig, *, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    s = seq
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, s)),
                               jnp.int32),
        "loss_mask": jnp.ones((batch, s), jnp.float32),
    }
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
    if cfg.n_image_tokens:
        out["image_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.bfloat16)
    return out
