"""Production training launcher.

On a real TPU slice this runs under the multi-host runtime (one process per
host; jax.distributed.initialize) with the production mesh; on CPU it runs
the same code end-to-end with ``--tiny`` configs for validation.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --tiny \
        --steps 50 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

``--pods N`` (N > 1) switches to the multi-pod cluster mode: N replicated
data-parallel pods training through the partition-tolerant compressed
exchange (``repro.ft.crosspod``), with ``net_partition`` / ``disk_full``
chaos targeting the pod set.  Under ``--chaos-assert`` the run must finish
with zero split-brain fingerprint divergences, a clean committed-index
audit, and final params bit-identical to a fault-free reference cluster:

    PYTHONPATH=src python -m repro.launch.train --tiny --pods 3 \
        --steps 12 --global-batch 2 --seq-len 32 --chaos unstable \
        --chaos-seed 29 --chaos-assert
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.chaos import DISK_FULL, NET_PARTITION, TRAIN_KINDS
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.distributed import params as pshard
from repro.distributed.sharding import use_rules
from repro.distributed.steps import make_train_step
from repro.ft import (CheckpointStore, DynamicInterval, FaultInjector,
                      PodTrainingCluster, TrainingCoordinator, tree_digest)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.serve import (add_chaos_args, add_trace_args, make_chaos,
                                make_obs)
from repro.models import lm
from repro.obs import profile_jit, save_profiles
from repro.optim import AdamWConfig, adamw_init


def cluster_main(cfg, mesh, args) -> None:
    """Multi-pod mode: quorum trains through partitions, minority pods park
    and catch up from the quorum checkpoint at heal."""
    # --chaos-assert needs the exact per-step split-brain check; otherwise
    # fingerprints are sampled (tree_digest syncs every leaf to host)
    fingerprint_every = 1 if args.chaos_assert else args.fingerprint_every

    def build(chaos_engine, ckpt_dir, ctx=None):
        params = lm.init_params(jax.random.key(args.seed), cfg)
        pipeline = SyntheticTokenPipeline(
            DataConfig(args.global_batch, args.seq_len, seed=args.seed), cfg)
        tracer = ctx.tracer if ctx is not None else None
        return PodTrainingCluster(
            cfg=cfg, params=params, pipeline=pipeline,
            store=CheckpointStore(ckpt_dir, tracer=tracer),
            n_pods=args.pods, opt_cfg=AdamWConfig(lr=args.lr),
            q_chunk=min(1024, args.seq_len), xent_chunk=512,
            chaos=chaos_engine, fingerprint_every=fingerprint_every,
            tracer=tracer,
            registry=ctx.registry if ctx is not None else None)

    ctx = make_obs(args)
    chaos = make_chaos(args, kinds=(NET_PARTITION, DISK_FULL),
                       n_targets=args.pods,
                       horizon=args.chaos_horizon or args.steps,
                       tracer=ctx.tracer)
    with use_rules(mesh):
        cluster = build(chaos, args.ckpt_dir, ctx)
        t0 = time.time()
        report = cluster.run(args.steps)
        dt = time.time() - t0
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"pods={args.pods} steps={report.steps_completed} "
          f"rounds={report.rounds} ckpts={report.checkpoints} "
          f"compression={cluster.exchange.compression_ratio:.1f}x")
    print(f"partitions {report.partitions} parked-pod-rounds "
          f"{report.parked_pod_rounds} heals {report.heals} catchups "
          f"{report.catchups} disk-full {report.disk_full_events} "
          f"enospc-retries {report.enospc_retries} | split-brain "
          f"{report.split_brain_divergences} index-violations "
          f"{report.index_violations} | fingerprints "
          f"{report.fingerprints_taken} taken / "
          f"{report.fingerprints_skipped} skipped (every "
          f"{fingerprint_every})")
    if chaos is not None:
        print(f"chaos applied: {dict(chaos.applied_by_kind)}")
    if ctx.finish() is not None:
        print(f"trace: {len(ctx.recorder.dumps)} dump(s) + metrics under "
              f"{args.trace_dir}")
    print(f"final loss {report.final_loss:.4f} wall={dt:.1f}s "
          f"({dt / max(report.steps_completed, 1):.2f}s/step)")
    if args.chaos_assert:
        assert chaos is not None, "--chaos-assert needs an active chaos run"
        assert chaos.applied, "chaos trace fired no events"
        assert report.steps_completed == args.steps, (
            f"cluster did not survive: {report.steps_completed}/"
            f"{args.steps} steps")
        assert report.split_brain_divergences == 0, (
            f"{report.split_brain_divergences} split-brain fingerprint "
            "divergence(s): two components advanced independently")
        assert report.index_violations == 0, (
            "committed checkpoint index failed its audit after chaos")
        assert all(np.isfinite(report.losses)), "non-finite loss in cluster"
        with tempfile.TemporaryDirectory() as ref_dir, use_rules(mesh):
            reference = build(None, ref_dir)
            ref = reference.run(args.steps)
        ref_digest = tree_digest(reference.params[0])
        mismatched = [p for p in range(args.pods)
                      if tree_digest(cluster.params[p]) != ref_digest]
        assert ref.steps_completed == args.steps
        assert not mismatched, (
            f"pods {mismatched} are not bit-identical to the fault-free "
            f"reference after heal (digest {ref_digest[:12]})")
        print(f"chaos-assert OK: {report.steps_completed} steps, "
              f"{report.heals} heals, all {args.pods} pods bit-identical "
              "to the fault-free reference, 0 split-brain divergences")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-gamma-s", type=float, default=5.0)
    ap.add_argument("--mesh", choices=("debug", "single", "multi"),
                    default="debug")
    ap.add_argument("--inject-mtbf-steps", type=float, default=0.0,
                    help="simulate failures every ~N steps (0 = off)")
    ap.add_argument("--pods", type=int, default=1,
                    help="N > 1: multi-pod cluster mode through the "
                         "partition-tolerant exchange")
    ap.add_argument("--fingerprint-every", type=int, default=8,
                    help="cluster mode: take the split-brain sha1 "
                         "fingerprint every N applied steps (forced to 1 "
                         "under --chaos-assert)")
    ap.add_argument("--seed", type=int, default=0)
    add_chaos_args(ap)
    add_trace_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    mesh = (make_debug_mesh() if args.mesh == "debug" else
            make_production_mesh(multi_pod=(args.mesh == "multi")))
    if args.pods > 1:
        cluster_main(cfg, mesh, args)
        return

    ctx = make_obs(args)
    with use_rules(mesh):
        params = lm.init_params(jax.random.key(args.seed), cfg)
        opt_state = adamw_init(params)
        abstract = jax.eval_shape(lambda: params)
        psh = pshard.param_shardings(abstract, mesh)
        params = jax.device_put(params, psh)
        step_fn = jax.jit(make_train_step(
            cfg, AdamWConfig(lr=args.lr), accum_steps=args.accum,
            q_chunk=min(1024, args.seq_len), xent_chunk=512,
            total_steps=args.steps))
        profiled = None
        if ctx.enabled:
            # the wrapper blocks on outputs each call (exact wall times at
            # the cost of dispatch overlap) — opt-in with --trace-dir
            profiled = profile_jit(step_fn, name="train_step",
                                   registry=ctx.registry, tracer=ctx.tracer)
            step_fn = profiled

        pipeline = SyntheticTokenPipeline(
            DataConfig(args.global_batch, args.seq_len, seed=args.seed), cfg)
        injector = (FaultInjector(mtbf_steps=args.inject_mtbf_steps,
                                  seed=args.seed,
                                  horizon_steps=args.steps)
                    if args.inject_mtbf_steps else None)
        chaos = make_chaos(args, kinds=TRAIN_KINDS, n_targets=1,
                           horizon=args.chaos_horizon or args.steps,
                           tracer=ctx.tracer)
        coord = TrainingCoordinator(
            train_step=step_fn, params=params, opt_state=opt_state,
            pipeline=pipeline,
            store=CheckpointStore(args.ckpt_dir, tracer=ctx.tracer),
            interval=DynamicInterval(gamma_s=args.ckpt_gamma_s),
            injector=injector, chaos=chaos, tracer=ctx.tracer,
            registry=ctx.registry)

        t0 = time.time()
        report = coord.run(args.steps)
        dt = time.time() - t0

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={report.steps_completed} failures={report.failures} "
          f"restores={report.restores} ckpts={report.checkpoints}")
    if chaos is not None:
        print(f"chaos applied: {dict(chaos.applied_by_kind)} | "
              f"nan-rollbacks {report.nan_rollbacks} skipped-batches "
              f"{report.skipped_batches} ckpt-fallbacks "
              f"{report.ckpt_fallbacks} ckpt-corruptions "
              f"{report.ckpt_corruptions} slowdowns {report.slowdowns} "
              f"backoff {report.backoff_steps:.0f} steps | partitions "
              f"{report.partitions} parked {report.parked_steps:.0f} "
              f"disk-full {report.disk_full_events} enospc-retries "
              f"{report.enospc_retries} index-violations "
              f"{report.index_violations}")
    n = max(1, len(report.losses) // 10)
    first = float(np.mean(report.losses[:n]))
    last = float(np.mean(report.losses[-n:]))
    print(f"loss: first10%={first:.4f} last10%={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}) "
          f"wall={dt:.1f}s ({dt / max(report.steps_completed, 1):.2f}s/step)")
    if profiled is not None:
        try:
            profiled.capture_cost(coord.params, coord.opt_state,
                                  coord.pipeline.batch_at(0))
        except Exception as e:   # cost_analysis is best-effort per backend
            print(f"profile: cost_analysis unavailable ({e})")
        prof = profiled.report()
        mean_ms = (prof["mean_s"] or 0.0) * 1e3
        print(f"profile: compile {prof['compile_s'] or 0.0:.2f}s, "
              f"{prof['calls']} steps mean {mean_ms:.1f} ms"
              + (f", {prof['flops']:.3g} FLOP/step"
                 if prof["flops"] else ""))
        save_profiles(f"{args.trace_dir}/profile.json", [profiled])
    if ctx.finish() is not None:
        rec = ctx.recorder
        print(f"trace: {len(rec.dumps)} dump(s) + metrics under "
              f"{args.trace_dir} (faults seen {dict(rec.faults_seen)}, "
              f"recoveries {dict(rec.recoveries_seen)})")
    if args.chaos_assert:
        assert chaos is not None, "--chaos-assert needs an active chaos run"
        assert chaos.applied, "chaos trace fired no events"
        assert report.steps_completed == args.steps, (
            f"training did not survive: {report.steps_completed}/"
            f"{args.steps} steps")
        assert report.restores > 0, "chaos run exercised no restore path"
        assert report.index_violations == 0, (
            "committed checkpoint index failed its audit after chaos")
        assert all(np.isfinite(report.losses)), "non-finite loss escaped the "\
            "NaN guard"
        print(f"chaos-assert OK: {report.steps_completed} steps, "
              f"{report.restores} restores, all losses finite, "
              "committed index clean")


if __name__ == "__main__":
    main()
