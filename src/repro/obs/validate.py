"""Flight-recorder dump validation (schema + required-span assertions).

Library functions validate a single dump pair; the CLI walks a trace
directory (as produced by ``--trace-dir``), validates every ``*.jsonl`` /
``*.trace.json`` file against the schema, and optionally requires that
named spans/events appear somewhere in the dumps — the CI obs smoke uses
this to assert the partition/heal recovery path was witnessed:

    python -m repro.obs.validate /tmp/obs_trace \
        --require-span crosspod.partition --require-span crosspod.heal
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from .recorder import load_jsonl

__all__ = ["validate_events", "validate_chrome", "validate_dir"]

_SPAN_KEYS = {"type", "name", "track", "t0", "t1", "span_id", "parent_id",
              "attrs"}
_EVENT_KEYS = {"type", "name", "track", "t", "span_id", "parent_id",
               "attrs"}


def validate_events(events: list[dict], *, where: str = "") -> list[str]:
    """Schema-check recorder dicts; returns a list of violations."""
    problems = []
    for i, rec in enumerate(events):
        loc = f"{where}#{i}"
        if not isinstance(rec, dict):
            problems.append(f"{loc}: not an object")
            continue
        kind = rec.get("type")
        if kind == "span":
            missing = _SPAN_KEYS - set(rec)
            if missing:
                problems.append(f"{loc}: span missing {sorted(missing)}")
                continue
            if not (isinstance(rec["t0"], (int, float))
                    and isinstance(rec["t1"], (int, float))
                    and rec["t1"] >= rec["t0"]):
                problems.append(f"{loc}: span has invalid t0/t1")
        elif kind == "event":
            missing = _EVENT_KEYS - set(rec)
            if missing:
                problems.append(f"{loc}: event missing {sorted(missing)}")
                continue
            if not isinstance(rec["t"], (int, float)):
                problems.append(f"{loc}: event has non-numeric t")
        else:
            problems.append(f"{loc}: unknown record type {kind!r}")
            continue
        if not isinstance(rec["name"], str) or not rec["name"]:
            problems.append(f"{loc}: empty name")
        if not isinstance(rec["attrs"], dict):
            problems.append(f"{loc}: attrs is not an object")
    return problems


def validate_chrome(doc: dict, *, where: str = "") -> list[str]:
    """Schema-check a Chrome ``trace_event`` JSON document."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{where}: missing traceEvents"]
    if not isinstance(doc["traceEvents"], list):
        return [f"{where}: traceEvents is not a list"]
    for i, ev in enumerate(doc["traceEvents"]):
        loc = f"{where}#{i}"
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                problems.append(f"{loc}: missing {k!r}")
        ph = ev.get("ph")
        if ph == "X" and "dur" not in ev:
            problems.append(f"{loc}: complete event missing dur")
        elif ph not in ("X", "i"):
            problems.append(f"{loc}: unexpected phase {ph!r}")
        if not isinstance(ev.get("ts", 0), (int, float)):
            problems.append(f"{loc}: non-numeric ts")
    return problems


def validate_dir(trace_dir: str, *, require_spans: list[str] | None = None
                 ) -> tuple[list[str], dict]:
    """Validate every dump in ``trace_dir``.  Returns (problems, summary)
    where summary has files/events counts and the set of span names seen."""
    problems: list[str] = []
    names: set[str] = set()
    jsonls = sorted(glob.glob(os.path.join(trace_dir, "*.jsonl")))
    chromes = sorted(glob.glob(os.path.join(trace_dir, "*.trace.json")))
    n_events = 0
    for path in jsonls:
        try:
            events = load_jsonl(path)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path}: unreadable ({e})")
            continue
        problems.extend(validate_events(events,
                                        where=os.path.basename(path)))
        names |= {rec.get("name") for rec in events
                  if isinstance(rec, dict) and isinstance(rec.get("name"),
                                                          str)}
        n_events += len(events)
    for path in chromes:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path}: unreadable ({e})")
            continue
        problems.extend(validate_chrome(doc,
                                        where=os.path.basename(path)))
    if not jsonls:
        problems.append(f"{trace_dir}: no *.jsonl dumps found")
    for span in (require_spans or []):
        if span not in names:
            problems.append(f"required span {span!r} missing from dumps "
                            f"(saw {len(names)} distinct names)")
    summary = {"jsonl_files": len(jsonls), "chrome_files": len(chromes),
               "events": n_events, "span_names": sorted(names)}
    return problems, summary


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="validate flight-recorder dumps in a trace directory")
    ap.add_argument("trace_dir")
    ap.add_argument("--require-span", action="append", default=[],
                    help="span/event name that must appear in some dump "
                         "(repeatable)")
    ap.add_argument("--list-spans", action="store_true",
                    help="print every distinct span/event name seen")
    args = ap.parse_args(argv)
    problems, summary = validate_dir(args.trace_dir,
                                     require_spans=args.require_span)
    print(f"{summary['jsonl_files']} jsonl + {summary['chrome_files']} "
          f"chrome dump(s), {summary['events']} event records, "
          f"{len(summary['span_names'])} distinct names")
    if args.list_spans:
        for name in summary["span_names"]:
            print(f"  {name}")
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    print("trace schema OK"
          + (f"; required spans present: {', '.join(args.require_span)}"
             if args.require_span else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
