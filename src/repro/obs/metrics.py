"""Unified metrics registry: counters / gauges / histograms with labels.

One registry per run absorbs what used to be ad-hoc Python ints scattered
across ``serve/metrics.py``, the training coordinator, and the cross-pod
cluster: an instrument is registered once by name and then incremented with
optional label key/values, so ``serve_drops_total{reason="shed"}`` and
``serve_drops_total{reason="rejected_on_arrival"}`` are two series of one
counter instead of two unrelated attributes.

Exporters:

* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` + escaped label values; histograms as
  cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count``);
* :meth:`MetricsRegistry.to_json` — nested plain dict, JSON-stable
  (sorted series keys);
* :meth:`MetricsRegistry.write` — both files into a directory (the
  launchers call it with the trace dir at run end).

Everything is plain Python floats and dicts — no dependencies, no
background threads, safe to leave enabled in hot paths (one dict lookup +
float add per increment).
"""
from __future__ import annotations

import json
import os

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "escape_label_value", "escape_help"]

DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def escape_label_value(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def escape_help(v: str) -> str:
    """Prometheus HELP escaping: backslash and newline only."""
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _label_key(labelnames: tuple, labels: dict) -> tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {sorted(labelnames)}, got {sorted(labels)}")
    return tuple((k, str(labels[k])) for k in labelnames)


class _Instrument:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.series: dict[tuple, float] = {}

    def value(self, **labels) -> float:
        return self.series.get(_label_key(self.labelnames, labels), 0.0)

    def total(self) -> float:
        return sum(self.series.values())

    def _series_name(self, key: tuple) -> str:
        if not key:
            return self.name
        inner = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in key)
        return f"{self.name}{{{inner}}}"

    def prom_lines(self) -> list[str]:
        return [f"{self._series_name(key)} {self.series[key]}"
                for key in sorted(self.series)]

    def to_json(self) -> dict:
        return {("|".join(f"{k}={v}" for k, v in key) if key else ""):
                self.series[key] for key in sorted(self.series)}


class Counter(_Instrument):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        self.series[key] = self.series.get(key, 0.0) + amount

    def set(self, value: float, **labels) -> None:
        """Direct set — the legacy-attribute compatibility shim's hook
        (``metrics.shed += 1`` reads then writes the series value)."""
        self.series[_label_key(self.labelnames, labels)] = float(value)


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.series[_label_key(self.labelnames, labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        self.series[key] = self.series.get(key, 0.0) + amount


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name, help, labelnames,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        # series value = observation count; detail per key below
        self._bucket_counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        counts = self._bucket_counts.setdefault(
            key, [0] * (len(self.buckets) + 1))
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sums[key] = self._sums.get(key, 0.0) + float(value)
        self.series[key] = self.series.get(key, 0.0) + 1.0

    def sum(self, **labels) -> float:
        return self._sums.get(_label_key(self.labelnames, labels), 0.0)

    def prom_lines(self) -> list[str]:
        lines = []
        for key in sorted(self.series):
            counts = self._bucket_counts[key]
            cum = 0
            for i, ub in enumerate(self.buckets):
                cum += counts[i]
                bkey = key + (("le", repr(float(ub))),)
                lines.append(
                    f"{self.name}_bucket{{"
                    + ",".join(f'{k}="{escape_label_value(v)}"'
                               for k, v in bkey) + f"}} {cum}")
            cum += counts[-1]
            bkey = key + (("le", "+Inf"),)
            lines.append(
                f"{self.name}_bucket{{"
                + ",".join(f'{k}="{escape_label_value(v)}"'
                           for k, v in bkey) + f"}} {cum}")
            inner = ",".join(f'{k}="{escape_label_value(v)}"'
                             for k, v in key)
            braces = f"{{{inner}}}" if key else ""
            lines.append(f"{self.name}_sum{braces} {self._sums[key]}")
            lines.append(f"{self.name}_count{braces} "
                         f"{int(self.series[key])}")
        return lines

    def to_json(self) -> dict:
        out = {}
        for key in sorted(self.series):
            skey = "|".join(f"{k}={v}" for k, v in key) if key else ""
            out[skey] = {
                "count": int(self.series[key]),
                "sum": self._sums[key],
                "buckets": {repr(float(ub)): c for ub, c in
                            zip(self.buckets, self._bucket_counts[key])},
                "inf": self._bucket_counts[key][-1],
            }
        return out


class MetricsRegistry:
    """Name -> instrument map.  Re-registering a name returns the existing
    instrument (so independent modules can share series); a kind mismatch
    is an error."""

    def __init__(self) -> None:
        self._instruments: dict[str, _Instrument] = {}

    def _get_or_make(self, cls, name, help, labelnames, **kw):
        inst = self._instruments.get(name)
        if inst is not None:
            if not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst
        inst = cls(name, help, tuple(labelnames), **kw)
        self._instruments[name] = inst
        return inst

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help, labelnames,
                                 buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        return self._instruments.get(name)

    def value(self, name: str, **labels) -> float:
        inst = self._instruments.get(name)
        return 0.0 if inst is None else inst.value(**labels)

    # -- exporters ------------------------------------------------------------
    def to_prometheus(self) -> str:
        lines = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {escape_help(inst.help)}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.prom_lines())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        return {name: {"kind": inst.kind, "help": inst.help,
                       "series": inst.to_json()}
                for name, inst in sorted(self._instruments.items())}

    def write(self, out_dir: str) -> tuple[str, str]:
        """Write ``metrics.json`` + ``metrics.prom`` into ``out_dir``."""
        os.makedirs(out_dir, exist_ok=True)
        jpath = os.path.join(out_dir, "metrics.json")
        with open(jpath, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        ppath = os.path.join(out_dir, "metrics.prom")
        with open(ppath, "w") as f:
            f.write(self.to_prometheus())
        return jpath, ppath
