"""Flight recorder: a bounded ring buffer of trace records with
fault-triggered dumps.

The recorder is the black box of a chaos run: every span/event the tracer
emits lands in a ``deque(maxlen=capacity)``, so steady-state memory is
bounded no matter how long the run.  When a fault fires
(:meth:`on_fault`) or a recovery path is taken (:meth:`on_recovery`) —
and ``dump_on_fault`` is set — the last ``window_s`` seconds of events are
dumped twice:

* ``NNNN_<label>.jsonl`` — one JSON object per line, the loadable form
  (:func:`load_jsonl`);
* ``NNNN_<label>.trace.json`` — Chrome ``trace_event`` format
  (``chrome://tracing`` / Perfetto): spans as ``"X"`` complete events,
  point events as ``"i"`` instants.

Dumps are capped at ``max_dumps`` per run so an unstable-profile chaos
storm cannot fill the disk the checkpoints live on; a final explicit
:meth:`dump` (the launchers' ``run_end`` dump) does not count against the
cap.  The clock is injectable for deterministic tests.
"""
from __future__ import annotations

import collections
import json
import os
import time

__all__ = ["FlightRecorder", "load_jsonl", "to_chrome"]


def to_chrome(events: list[dict]) -> dict:
    """Convert recorder dicts to Chrome ``trace_event`` JSON (µs units)."""
    out = []
    for rec in events:
        args = {k: v for k, v in (rec.get("attrs") or {}).items()
                if v is not None}
        common = {"name": rec["name"], "pid": 0, "tid": rec.get("track",
                                                               "main"),
                  "args": args}
        if rec["type"] == "span":
            out.append({**common, "ph": "X",
                        "ts": rec["t0"] * 1e6,
                        "dur": max(rec["t1"] - rec["t0"], 0.0) * 1e6})
        else:
            out.append({**common, "ph": "i", "ts": rec["t"] * 1e6,
                        "s": "t"})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def load_jsonl(path: str) -> list[dict]:
    """Load a dumped ``.jsonl`` flight-recorder file back into dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


class FlightRecorder:
    """Bounded ring of trace records + fault/recovery-triggered dumps."""

    def __init__(self, capacity: int = 8192, *, out_dir: str | None = None,
                 window_s: float | None = None, dump_on_fault: bool = False,
                 max_dumps: int = 64, clock=time.monotonic):
        self.capacity = max(1, int(capacity))
        self.out_dir = out_dir
        self.window_s = window_s
        self.dump_on_fault = dump_on_fault
        self.max_dumps = max_dumps
        self.clock = clock
        self._ring: collections.deque[dict] = collections.deque(
            maxlen=self.capacity)
        self._seq = 0
        self.dumps: list[str] = []        # jsonl paths written, in order
        self.faults_seen: collections.Counter = collections.Counter()
        self.recoveries_seen: collections.Counter = collections.Counter()

    # -- ingest ---------------------------------------------------------------
    def record(self, rec: dict) -> None:
        self._ring.append(rec)

    def __len__(self) -> int:
        return len(self._ring)

    def snapshot(self) -> list[dict]:
        """Current ring contents, oldest first, filtered to ``window_s``."""
        events = list(self._ring)
        if self.window_s is None:
            return events
        cutoff = self.clock() - self.window_s
        return [e for e in events
                if e.get("t1", e.get("t", 0.0)) >= cutoff]

    # -- dump triggers --------------------------------------------------------
    def on_fault(self, kind: str, *, step: int | None = None) -> str | None:
        self.faults_seen[kind] += 1
        if self.dump_on_fault:
            return self._auto_dump(f"fault_{kind}")
        return None

    def on_recovery(self, kind: str) -> str | None:
        self.recoveries_seen[kind] += 1
        if self.dump_on_fault:
            return self._auto_dump(f"recovery_{kind}")
        return None

    def _auto_dump(self, label: str) -> str | None:
        if len(self.dumps) >= self.max_dumps:
            return None
        return self.dump(label)

    # -- dump -----------------------------------------------------------------
    def dump(self, label: str = "manual") -> str | None:
        """Write the windowed ring as JSONL + Chrome trace.  Returns the
        JSONL path (None when no ``out_dir`` is configured)."""
        if self.out_dir is None:
            return None
        os.makedirs(self.out_dir, exist_ok=True)
        events = self.snapshot()
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in label)
        base = os.path.join(self.out_dir, f"{self._seq:04d}_{safe}")
        self._seq += 1
        jsonl = base + ".jsonl"
        with open(jsonl, "w") as f:
            for rec in events:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        with open(base + ".trace.json", "w") as f:
            json.dump(to_chrome(events), f, sort_keys=True)
        self.dumps.append(jsonl)
        return jsonl
