"""Profiling hooks for jitted step functions.

:func:`profile_jit` wraps a jit'd callable and records, into the unified
metrics registry and (optionally) the span tracer:

* **compile time** — the first call pays trace + XLA compile; its wall time
  lands in ``profile_compile_seconds{step=<name>}`` (the steady-state
  histogram starts at call 2);
* **per-step wall time** — every later call is timed end-to-end
  (``jax.block_until_ready`` on the outputs, so async dispatch cannot hide
  the work) into ``profile_step_seconds`` histogram series;
* **cost analysis** — :meth:`ProfiledFn.capture_cost` lowers + compiles the
  wrapped function for a concrete arg set and normalizes
  ``Compiled.cost_analysis()`` via :func:`repro.analysis.hlo.
  normalize_cost_analysis`, recording FLOPs / bytes-accessed gauges.

:func:`save_profiles` writes the collected profiles as JSON for
``benchmarks/roofline.py --profile``, which joins measured step times
against the analytic roofline terms (achieved vs. peak FLOP/s).

``block_until_ready`` makes the wrapper a synchronization point, so the
hooks are opt-in (the launchers enable them only under ``--trace-dir``);
results are bit-identical either way — only dispatch overlap changes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from repro.analysis.hlo import normalize_cost_analysis

from .metrics import MetricsRegistry
from .trace import NULL_TRACER

__all__ = ["ProfiledFn", "profile_jit", "save_profiles"]

STEP_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
                2.5, 5.0, 15.0, 60.0)


@dataclasses.dataclass
class _Stats:
    compile_s: float | None = None
    calls: int = 0               # steady-state calls (compile call excluded)
    total_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0
    flops: float | None = None
    bytes_accessed: float | None = None


class ProfiledFn:
    """A jit'd callable wrapped with wall-time + compile-time recording."""

    def __init__(self, fn, *, name: str, registry: MetricsRegistry | None,
                 tracer=None, clock=time.perf_counter):
        self.fn = fn
        self.name = name
        self.registry = registry or MetricsRegistry()
        self.tracer = tracer or NULL_TRACER
        self.clock = clock
        self.stats = _Stats()
        self._g_compile = self.registry.gauge(
            "profile_compile_seconds",
            "first-call (trace + XLA compile) wall time per step fn",
            ("step",))
        self._h_step = self.registry.histogram(
            "profile_step_seconds",
            "steady-state per-call wall time per step fn", ("step",),
            buckets=STEP_BUCKETS)
        self._g_flops = self.registry.gauge(
            "profile_step_flops",
            "XLA cost_analysis FLOPs per call of the step fn", ("step",))
        self._g_bytes = self.registry.gauge(
            "profile_step_bytes_accessed",
            "XLA cost_analysis bytes accessed per call", ("step",))

    def __call__(self, *args, **kwargs):
        t0 = self.clock()
        out = self.fn(*args, **kwargs)
        jax.block_until_ready(out)
        dt = self.clock() - t0
        st = self.stats
        if st.compile_s is None:
            st.compile_s = dt
            self._g_compile.set(dt, step=self.name)
            self.tracer.event("profile.compile", step=self.name, seconds=dt)
        else:
            st.calls += 1
            st.total_s += dt
            st.min_s = min(st.min_s, dt)
            st.max_s = max(st.max_s, dt)
            self._h_step.observe(dt, step=self.name)
        return out

    # -- optional XLA cost analysis -------------------------------------------
    def capture_cost(self, *args, **kwargs) -> dict:
        """Lower + compile for these concrete args and record FLOPs/bytes
        (uses the jit cache's lowering path; one extra compile at most)."""
        lowered = self.fn.lower(*args, **kwargs)
        cost = normalize_cost_analysis(lowered.compile().cost_analysis())
        flops = float(cost.get("flops", 0.0))
        nbytes = float(cost.get("bytes accessed", 0.0))
        self.stats.flops = flops
        self.stats.bytes_accessed = nbytes
        self._g_flops.set(flops, step=self.name)
        self._g_bytes.set(nbytes, step=self.name)
        return cost

    def report(self) -> dict:
        st = self.stats
        mean = st.total_s / st.calls if st.calls else None
        return {
            "name": self.name,
            "compile_s": st.compile_s,
            "calls": st.calls,
            "total_s": st.total_s,
            "mean_s": mean,
            "min_s": None if st.calls == 0 else st.min_s,
            "max_s": None if st.calls == 0 else st.max_s,
            "flops": st.flops,
            "bytes_accessed": st.bytes_accessed,
            "achieved_flops_per_s": (st.flops / mean
                                     if st.flops and mean else None),
        }


def profile_jit(fn, *, name: str, registry: MetricsRegistry | None = None,
                tracer=None, clock=time.perf_counter) -> ProfiledFn:
    """Wrap a jit'd callable with compile/step wall-time recording."""
    return ProfiledFn(fn, name=name, registry=registry, tracer=tracer,
                      clock=clock)


def save_profiles(path: str, profiled: list[ProfiledFn]) -> str:
    """Write ``[ProfiledFn.report(), ...]`` as the ``profile.json``
    artifact ``benchmarks/roofline.py --profile`` consumes."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump([p.report() for p in profiled], f, indent=1,
                  sort_keys=True)
    return path
