"""Zero-dependency structured span tracer.

The tracing unit is a *span* — a named interval with monotonic-clock
timestamps, key/value attributes, and a parent link (nesting follows the
tracer's span stack).  Point-in-time *events* hang off the current span.
Completed spans and events are emitted as plain dicts into a
:class:`~repro.obs.recorder.FlightRecorder` ring buffer (or any object with
a ``record(dict)`` method), so the tracer itself holds no history.

Two properties the fault-tolerance layers rely on:

* **off-hot-path when disabled** — :data:`NULL_TRACER` (and any tracer
  constructed with ``enabled=False``) answers every call with a cached
  no-op: ``span()`` costs one branch and returns a shared null context
  manager, ``event()``/``fault()``/``recovery()`` return immediately.
  Instrumented code therefore never needs ``if tracer is not None`` guards;
* **deterministic timestamps on demand** — the clock is injectable
  (``clock=``), so tests drive spans with a fake counter and dumps become
  byte-stable.

Span names form the witness vocabulary of the fault taxonomy (see the
Observability section of ROADMAP.md): every recovery path emits a
``recover.<fault_kind>`` annotation via :meth:`Tracer.recovery`, and every
injected fault a ``fault.<fault_kind>`` annotation via :meth:`Tracer.fault`
— both of which also arm the flight recorder's dump-on-fault trigger.
"""
from __future__ import annotations

import time

__all__ = ["Span", "Tracer", "NULL_TRACER"]


class _NullSpan:
    """Shared no-op span: context manager + attribute sink."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """One live span.  Use as a context manager; emitted on exit."""

    __slots__ = ("tracer", "name", "track", "attrs", "span_id", "parent_id",
                 "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 attrs: dict, span_id: int, parent_id: int | None):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = 0.0
        self.t1 = 0.0

    def set(self, **attrs) -> "Span":
        """Attach attributes mid-span (e.g. an outcome discovered late)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.t0 = self.tracer.clock()
        self.tracer._stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t1 = self.tracer.clock()
        stack = self.tracer._stack
        if stack and stack[-1] is self:
            stack.pop()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self.tracer._emit({
            "type": "span", "name": self.name, "track": self.track,
            "t0": self.t0, "t1": self.t1, "span_id": self.span_id,
            "parent_id": self.parent_id, "attrs": self.attrs,
        })
        return False


class Tracer:
    """Emits spans/events into a recorder.  Disabled = one-branch no-op."""

    def __init__(self, recorder=None, *, clock=time.monotonic,
                 enabled: bool = True):
        self.recorder = recorder
        self.clock = clock
        self.enabled = enabled and recorder is not None
        self._stack: list[Span] = []
        self._next_id = 1

    # -- emission -------------------------------------------------------------
    def _emit(self, rec: dict) -> None:
        if self.recorder is not None:
            self.recorder.record(rec)

    def _ids(self) -> tuple[int, int | None]:
        sid = self._next_id
        self._next_id += 1
        parent = self._stack[-1].span_id if self._stack else None
        return sid, parent

    # -- spans ----------------------------------------------------------------
    def span(self, name: str, *, track: str = "main", **attrs):
        """Open a nested span (context manager)."""
        if not self.enabled:
            return _NULL_SPAN
        sid, parent = self._ids()
        return Span(self, name, track, attrs, sid, parent)

    def complete(self, name: str, t0: float, t1: float, *,
                 track: str = "main", **attrs) -> None:
        """Emit an already-timed span directly, bypassing the span stack.

        The thread-safe entry point: the async checkpoint writer times its
        own interval and reports it here without touching the (single-
        threaded) nesting stack."""
        if not self.enabled:
            return
        sid = self._next_id
        self._next_id += 1
        self._emit({"type": "span", "name": name, "track": track,
                    "t0": t0, "t1": t1, "span_id": sid, "parent_id": None,
                    "attrs": attrs})

    # -- point events ---------------------------------------------------------
    def event(self, name: str, *, track: str = "main", **attrs) -> None:
        if not self.enabled:
            return
        sid, parent = self._ids()
        self._emit({"type": "event", "name": name, "track": track,
                    "t": self.clock(), "span_id": sid, "parent_id": parent,
                    "attrs": attrs})

    # -- fault / recovery annotations (flight-recorder triggers) --------------
    def fault(self, kind: str, *, step: int | None = None, **attrs) -> None:
        """Annotate an injected/observed fault: emits ``fault.<kind>`` and
        arms the recorder's dump-on-fault trigger."""
        if not self.enabled:
            return
        self.event(f"fault.{kind}", step=step, **attrs)
        if self.recorder is not None:
            self.recorder.on_fault(kind, step=step)

    def recovery(self, kind: str, **attrs) -> None:
        """Annotate a recovery path being taken: emits ``recover.<kind>``
        and triggers a flight-recorder dump (the dump that *contains* the
        recovery spans, unlike the at-fault dump which shows the lead-up)."""
        if not self.enabled:
            return
        self.event(f"recover.{kind}", **attrs)
        if self.recorder is not None:
            self.recorder.on_recovery(kind)


#: the canonical disabled tracer — safe default for every instrumented layer
NULL_TRACER = Tracer(None, enabled=False)
