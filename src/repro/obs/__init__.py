"""repro.obs — flight-recorder tracing, unified metrics, profiling hooks.

The observability subsystem that makes the fault-taxonomy recovery paths
*witnessable* instead of merely survivable:

``trace.py``
    Zero-dependency structured span tracer: nested spans with
    monotonic-clock timestamps (injectable for determinism), per-event
    attributes, and ``fault.<kind>`` / ``recover.<kind>`` annotations.
    :data:`NULL_TRACER` is the always-safe disabled default — one branch on
    the hot path, no allocation.

``recorder.py``
    Bounded flight-recorder ring buffer; dumps the last-N-seconds window as
    JSONL + Chrome ``trace_event`` JSON whenever a fault fires or a
    recovery path is taken (``dump_on_fault``), capped per run.

``metrics.py``
    Unified counters/gauges/histograms with labeled series, Prometheus-text
    and JSON exporters.  Absorbs ``serve/metrics.py`` and the training
    coordinator's inline counters behind one API.

``profile.py``
    Wraps jitted step functions: compile time, per-step wall time, optional
    ``cost_analysis`` FLOPs via ``repro.analysis.hlo`` — feeding
    ``benchmarks/roofline.py --profile``.

``validate.py``
    Dump schema validation + required-span assertions (the CI obs smoke).

The launchers build one :class:`ObsContext` via :func:`setup` from their
``--trace-dir`` / ``--trace-dump-on-fault`` flags and thread
``ctx.tracer`` / ``ctx.registry`` through the engine, coordinator, cluster,
checkpoint store and chaos engine.  With no trace dir everything collapses
to :data:`NULL_TRACER` and a detached registry: chaos-matrix replays are
byte-identical with tracing on or off, and the disabled recorder costs one
branch per call site.
"""
from __future__ import annotations

import dataclasses
import time

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .profile import ProfiledFn, profile_jit, save_profiles
from .recorder import FlightRecorder, load_jsonl, to_chrome
from .trace import NULL_TRACER, Span, Tracer

__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "ObsContext",
    "ProfiledFn",
    "Span",
    "Tracer",
    "load_jsonl",
    "profile_jit",
    "save_profiles",
    "setup",
    "to_chrome",
]


@dataclasses.dataclass
class ObsContext:
    """One run's observability handles (tracer + recorder + registry)."""

    tracer: Tracer
    recorder: FlightRecorder | None
    registry: MetricsRegistry

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    def finish(self, label: str = "run_end") -> str | None:
        """Final dump + metrics export into the trace dir (no-op when
        tracing is disabled).  Returns the JSONL dump path."""
        if self.recorder is None or self.recorder.out_dir is None:
            return None
        path = self.recorder.dump(label)
        self.registry.write(self.recorder.out_dir)
        return path


def setup(trace_dir: str | None = None, *, dump_on_fault: bool = False,
          capacity: int = 8192, window_s: float | None = None,
          max_dumps: int = 64, clock=time.monotonic,
          registry: MetricsRegistry | None = None) -> ObsContext:
    """Build an :class:`ObsContext`.  ``trace_dir=None`` disables tracing
    (NULL tracer, no recorder) but still returns a live registry."""
    registry = registry or MetricsRegistry()
    if trace_dir is None:
        return ObsContext(tracer=NULL_TRACER, recorder=None,
                          registry=registry)
    recorder = FlightRecorder(capacity, out_dir=trace_dir,
                              window_s=window_s,
                              dump_on_fault=dump_on_fault,
                              max_dumps=max_dumps, clock=clock)
    return ObsContext(tracer=Tracer(recorder, clock=clock),
                      recorder=recorder, registry=registry)
