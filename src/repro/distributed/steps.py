"""Train / serve step builders (pjit-able, mesh-agnostic)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None, *,
                    accum_steps: int = 1, q_chunk: int = 1024,
                    xent_chunk: int = 512, warmup: int = 100,
                    total_steps: int = 10_000, grad_shardings=None):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.  ``accum_steps > 1`` scans over microbatches (sequential
    gradient accumulation) so activation memory is bounded by one microbatch.
    ``grad_shardings`` (a NamedSharding tree mirroring params) constrains the
    accumulated-gradient buffer -- under ZeRO-1 this turns the per-microbatch
    gradient all-reduce into a reduce-scatter onto the optimizer shards.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(p, mb):
        loss, metrics = lm.forward_train(p, cfg, mb, q_chunk=q_chunk,
                                         xent_chunk=xent_chunk)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                if grad_shardings is not None:
                    gsum = jax.lax.with_sharding_constraint(gsum,
                                                            grad_shardings)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if grad_shardings is not None:
                zeros = jax.lax.with_sharding_constraint(zeros,
                                                         grad_shardings)
            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}
        lr_scale = cosine_schedule(opt_state["step"], warmup=warmup,
                                   total=total_steps)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state, lr_scale=lr_scale)
        out = {"loss": loss, **om}
        return params, opt_state, out

    return train_step


def make_serve_step(cfg: ModelConfig, *, cache_axes=None):
    """One greedy decode step: (params, cache, tokens (B,1), pos) ->
    (next_tokens (B,1), logits fp32, cache).  ``pos`` may be a scalar
    (static batch, all rows at the same position) or a (B,) vector
    (continuous batching, per-slot positions).

    With ``cache_axes`` (the per-leaf batch-axis pytree from
    ``repro.serve.snapshot.cache_batch_axes``) the returned step takes an
    extra ``live`` (B,) bool argument and only commits cache writes for live
    rows — freed slots keep their previous row bit-identical.  Without this,
    idle slots' stale ``last_token``/``pos`` would silently rewrite cache
    rows every tick: harmless for dense KV only because prefill overwrites
    the whole row on reuse, but fatal for recurrent (RWKV / RG-LRU) state,
    which accumulates."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = lm.decode_step(params, cfg, cache, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache

    if cache_axes is None:
        return serve_step

    def serve_step_masked(params, cache, tokens, pos, live):
        logits, new_cache = lm.decode_step(params, cfg, cache, tokens, pos)

        def commit(new, old, axis):
            shape = [1] * new.ndim
            shape[axis] = new.shape[axis]
            return jnp.where(live.reshape(shape), new, old)

        new_cache = jax.tree.map(commit, new_cache, cache, cache_axes)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, new_cache

    return serve_step_masked


def make_prefill_step(cfg: ModelConfig, cache_len: int, *,
                      q_chunk: int = 1024, with_last_idx: bool = False):
    """``with_last_idx=True`` returns ``prefill_step(params, batch,
    last_idx)`` where ``last_idx`` (B,) picks each row's true last prompt
    position (bucket-padded prompts, see ``lm.prefill``)."""
    if with_last_idx:
        def prefill_last_idx_step(params, batch, last_idx):
            return lm.prefill(params, cfg, batch, cache_len, q_chunk=q_chunk,
                              last_idx=last_idx)

        return prefill_last_idx_step

    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, cache_len, q_chunk=q_chunk)

    return prefill_step
