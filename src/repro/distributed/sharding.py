"""Logical-axis sharding rules (MaxText-style) decoupled from model code.

Model code annotates activations with *logical* axis names::

    x = constrain(x, ("batch", "seq", "embed"))

Inside a ``use_rules(mesh, rules)`` scope these map to mesh axes and become
``jax.lax.with_sharding_constraint``; outside any scope they are no-ops, so
the same model runs single-device (tests) and multi-pod (dry-run/train).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    # sequence-parallel residual stream between blocks (Megatron-SP): the
    # remat-saved carries shrink by the model-axis extent; XLA inserts the
    # all-gather/reduce-scatter pairs around the TP matmuls
    "seq_resid": "model",
    "kv_seq": "model",        # sequence-sharded KV cache (flash-decoding)
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",           # d_ff tensor parallel
    "vocab": "model",
    "experts": "model",       # expert parallel
    "expert_capacity": None,
    "fsdp": "data",           # secondary param shard axis
    "frames": None,
    "lru": "model",
}


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def current_rules() -> dict:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict | None = None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", DEFAULT_RULES)
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


def logical_to_spec(logical_axes: tuple[str | None, ...],
                    rules: dict | None = None,
                    mesh: Mesh | None = None) -> P:
    rules = rules if rules is not None else current_rules()
    mesh = mesh if mesh is not None else current_mesh()
    axis_names = set(mesh.axis_names) if mesh is not None else set()
    parts = []
    for ax in logical_axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
        elif isinstance(m, tuple):
            kept = tuple(a for a in m if a in axis_names)
            parts.append(kept if kept else None)
        else:
            parts.append(m if m in axis_names else None)
    return P(*parts)


def constrain(x: jax.Array, logical_axes: tuple[str | None, ...]) -> jax.Array:
    """Apply a sharding constraint if a mesh scope is active, else no-op.
    Axes whose dimension does not divide the mapped mesh extent fall back to
    replication (e.g. batch=1 long_500k, whisper's 1500-frame sequences)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes)
    parts = []
    for dim, part in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if part is None:
            parts.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(part if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
