"""Parameter / optimizer / cache PartitionSpecs for the production mesh.

Strategy (TP on ``model``, ZeRO/FSDP on ``data``, DP across ``pod``):

* attention / MLP projections: input dim on ``data`` (FSDP), output dim on
  ``model`` (Megatron column-parallel); down/out projections transposed
  (row-parallel).
* MoE expert weights: experts on ``model`` (EP), input dim on ``data``.
* embeddings / lm_head: vocab on ``model``, embed dim on ``data``.
* RG-LRU / RWKV channel dims on ``model``; norms and scalar gains replicated.
* KV caches: batch on ``data``, sequence on ``model`` (flash-decoding style
  split -- GQA head counts rarely divide 16, sequence always does).
* optimizer moments: identical specs to their parameters.

Any dimension that does not divide its mesh axis falls back to replication
(granite-moe's vocab 49155, long_500k's batch 1); the roofline notes where
that costs bytes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# trailing-dims spec by (parent, leaf-name); "." matches any parent
_RULES: dict[tuple[str, str], tuple] = {
    (".", "embed"): ("model", "data"),
    (".", "lm_head"): ("data", "model"),
    (".", "enc_pos"): (None, None),
    (".", "dec_pos"): (None, None),
    # attention
    ("attn", "wq"): ("data", "model"),
    ("attn", "wk"): ("data", "model"),
    ("attn", "wv"): ("data", "model"),
    ("attn", "wo"): ("model", "data"),
    ("attn", "bq"): ("model",),
    ("attn", "bk"): ("model",),
    ("attn", "bv"): ("model",),
    ("attn", "bo"): (None,),
    ("xattn", "wq"): ("data", "model"),
    ("xattn", "wk"): ("data", "model"),
    ("xattn", "wv"): ("data", "model"),
    ("xattn", "wo"): ("model", "data"),
    ("xattn", "bq"): ("model",),
    ("xattn", "bk"): ("model",),
    ("xattn", "bv"): ("model",),
    ("xattn", "bo"): (None,),
    # dense MLP
    ("mlp", "w_gate"): ("data", "model"),
    ("mlp", "w_up"): ("data", "model"),
    ("mlp", "w_down"): ("model", "data"),
    ("mlp", "b_up"): ("model",),
    ("mlp", "b_down"): (None,),
    # MoE
    ("moe", "router"): ("data", None),
    ("moe", "w_gate"): ("model", "data", None),
    ("moe", "w_up"): ("model", "data", None),
    ("moe", "w_down"): ("model", None, "data"),
    # RG-LRU recurrent branch
    ("rec", "w_gate_branch"): ("data", "model"),
    ("rec", "w_rec_branch"): ("data", "model"),
    ("rec", "conv_w"): (None, "model"),
    ("rec", "conv_b"): ("model",),
    ("rec", "wa"): ("data", "model"),
    ("rec", "wx"): ("data", "model"),
    ("rec", "ba"): ("model",),
    ("rec", "bx"): ("model",),
    ("rec", "lam"): ("model",),
    ("rec", "w_out"): ("model", "data"),
    # RWKV time-mix
    ("tm", "wr"): ("data", "model"),
    ("tm", "wk"): ("data", "model"),
    ("tm", "wv"): ("data", "model"),
    ("tm", "wg"): ("data", "model"),
    ("tm", "wo"): ("model", "data"),
    ("tm", "lora_a"): ("data", None),
    ("tm", "lora_b"): (None, None, "data"),
    ("tm", "w_lora_a"): ("data", None),
    ("tm", "w_lora_b"): (None, "data"),
    ("tm", "mu"): (None, None),
    ("tm", "ww"): (None,),
    ("tm", "u"): (None,),
    ("tm", "ln_scale"): (None,),
    # RWKV channel-mix
    ("cm", "wk"): ("data", "model"),
    ("cm", "wv"): ("model", "data"),
    ("cm", "wr"): ("data", "model"),
    ("cm", "mu_k"): (None,),
    ("cm", "mu_r"): (None,),
}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = getattr(k, "name", None)
        out.append(str(name) if name is not None else "")
    return out


def _divisible(dim: int, axes, mesh: Mesh) -> bool:
    if axes is None:
        return True
    axes = axes if isinstance(axes, tuple) else (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0


def _pad_spec(trailing: tuple, ndim: int, shape, mesh: Mesh) -> P:
    lead = ndim - len(trailing)
    parts = [None] * lead + list(trailing)
    # drop axes the tensor cannot divide (falls back to replication)
    parts = [a if _divisible(shape[i], a, mesh) else None
             for i, a in enumerate(parts)]
    return P(*parts)


def spec_for_param(path, leaf, mesh: Mesh) -> P:
    names = [n for n in _path_names(path) if n]
    leaf_name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else "."
    rule = _RULES.get((parent, leaf_name)) or _RULES.get((".", leaf_name))
    if rule is None:
        # norms (ln1/ln2/...), scalar gains: replicate
        return P(*([None] * leaf.ndim))
    return _pad_spec(rule, leaf.ndim, leaf.shape, mesh)


def _strip_data(spec: P) -> P:
    """ZeRO-1 live params: TP on `model` only, replicated over `data`."""
    return P(*[None if p == "data" else p for p in spec])


def param_specs(abstract_params, mesh: Mesh, *, zero1: bool = False):
    full = jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf, mesh), abstract_params)
    if zero1:
        return jax.tree.map(_strip_data, full,
                            is_leaf=lambda x: isinstance(x, P))
    return full


def param_shardings(abstract_params, mesh: Mesh, *, zero1: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(abstract_params, mesh, zero1=zero1))


def opt_state_specs(abstract_opt, abstract_params, mesh: Mesh, *,
                    zero1: bool = False):
    """Moments (and the fp32 master copy under ZeRO-1) always keep the full
    data+model sharding -- that is what ZeRO-1 shards."""
    pspec = param_specs(abstract_params, mesh)      # full sharding
    out = {
        "mu": pspec,
        "nu": pspec,
        "step": P(),
    }
    if "master" in abstract_opt:
        out["master"] = pspec
    return out


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(abstract_batch, mesh: Mesh):
    """Leading dim = global batch on ("pod", "data")."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        if _divisible(leaf.shape[0], batch_axes, mesh):
            return P(batch_axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, abstract_batch)


def cache_specs(abstract_cache, cfg: ModelConfig, mesh: Mesh):
    """KV caches: (L, B, S, KV, D) -> batch on data, seq on model.
    Recurrent states: channel dims on model."""
    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if name in ("k", "v", "cross_k", "cross_v"):
            lead = leaf.ndim - 4                       # stacked layer axes
            parts = [None] * lead + ["data", "model", None, None]
        elif name == "S":                              # rwkv state (L,B,H,N,N)
            parts = [None, "data", "model", None, None]
        elif name in ("x_tm", "x_cm"):                 # (L, B, D)
            parts = [None, "data", "model"]
        elif name in ("h", "tail_h"):                  # (..., B, W)
            parts = [None] * (leaf.ndim - 2) + ["data", "model"]
        elif name in ("conv", "tail_conv"):            # (..., B, cw-1, W)
            parts = [None] * (leaf.ndim - 3) + ["data", None, "model"]
        else:
            parts = [None] * leaf.ndim
        parts = [a if _divisible(leaf.shape[i], a, mesh) else None
                 for i, a in enumerate(parts)]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(one, abstract_cache)
