"""whisper-small [arXiv:2212.04356]: enc-dec (12+12 layers), GELU MLP,
LayerNorm with bias; the conv audio frontend is a STUB -- ``input_specs``
supplies precomputed frame embeddings (1500 frames)."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    block_type="llama", norm_type="layernorm", mlp_type="gelu",
    use_bias=True, encoder_layers=12, n_frames=1500,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        encoder_layers=2, n_frames=32, max_decode_len=128)
