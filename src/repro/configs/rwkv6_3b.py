"""rwkv6-3b "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay,
head size 64 (40 heads), layernorm."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    block_type="llama", norm_type="layernorm", use_bias=False,
    rwkv=True,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-tiny", n_layers=2, d_model=128,
        n_heads=2, n_kv_heads=2, d_ff=256, vocab_size=256)
