"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]:
16 experts top-2, GQA kv=8."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    block_type="llama", norm_type="layernorm", use_bias=False,
    n_experts=16, top_k=2,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi35-moe-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
        n_experts=4, top_k=2)
