"""olmo-1b [arXiv:2402.00838]: dense, non-parametric LayerNorm, MHA (kv=16),
tied embeddings, vocab padded to 50304."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50_304,
    block_type="llama", norm_type="nonparametric_ln", tie_embeddings=True,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="olmo-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
