"""recurrentgemma-2b [arXiv:2402.19427]: RG-LRU + local attention, pattern
(rec, rec, attn), window 2048, MQA kv=1, head_dim 256."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256_000, head_dim=256,
    block_type="llama", norm_type="rmsnorm", tie_embeddings=True,
    rglru=True, rec_per_attn=2, window=2048, conv_width=4, lru_width=2560,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-tiny", n_layers=5, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=256, head_dim=32,
        window=16, lru_width=64)
