"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published configuration;
``get_config(name, tiny=True)`` returns the reduced same-family config used
by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "deepseek_coder_33b",
    "command_r_plus_104b",
    "olmo_1b",
    "granite_20b",
    "phi35_moe_42b",
    "granite_moe_1b",
    "recurrentgemma_2b",
    "llava_next_mistral_7b",
    "rwkv6_3b",
    "whisper_small",
)

# CLI ids (--arch <id>) -> module names
ALIASES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "command-r-plus-104b": "command_r_plus_104b",
    "olmo-1b": "olmo_1b",
    "granite-20b": "granite_20b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-small": "whisper_small",
}


def get_config(name: str, *, tiny: bool = False):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.tiny() if tiny else mod.CONFIG


def all_configs(*, tiny: bool = False):
    return {a: get_config(a, tiny=tiny) for a in ARCHS}
