"""granite-20b [arXiv:2405.04324]: llama-arch code model, MQA (kv=1)."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    block_type="llama", norm_type="layernorm", use_bias=True,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite-20b-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=256)
