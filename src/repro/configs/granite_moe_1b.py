"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]:
32 experts top-8, GQA kv=8."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    block_type="llama", norm_type="rmsnorm", tie_embeddings=True,
    n_experts=32, top_k=8,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
        n_experts=4, top_k=2)
