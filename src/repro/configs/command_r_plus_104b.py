"""command-r-plus-104b [hf:CohereForAI]: parallel attn+FFN block, GQA kv=8,
LayerNorm without bias, tied embeddings, no-bias projections."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab_size=256_000,
    block_type="parallel", norm_type="layernorm", use_bias=False,
    tie_embeddings=True, rope_theta=75_000.0,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="command-r-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=512)
