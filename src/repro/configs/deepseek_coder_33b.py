"""deepseek-coder-33b [arXiv:2401.14196]: llama-arch dense, GQA kv=8."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256,
    block_type="llama", norm_type="rmsnorm", rope_theta=100_000.0,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-coder-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
