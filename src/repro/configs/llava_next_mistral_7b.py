"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
Mistral-7B backbone; anyres vision frontend is a STUB -- ``input_specs``
supplies precomputed patch embeddings (576 base-resolution tokens)."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    block_type="llama", norm_type="rmsnorm", rope_theta=1_000_000.0,
    n_image_tokens=576,
)


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        n_image_tokens=8)
