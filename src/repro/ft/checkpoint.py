"""Light-weight pointer-based distributed checkpointing.

The paper's checkpoint design (Section 3.1.3 / 4.1) adapted to training:

* every host dumps only *its own shards* to host-local stable storage
  (``store_dir/host_XX/step_N/leaf.npy``);
* a tiny **global index** (JSON) holds only *pointers* -- leaf path ->
  (host, file, content hash, shape, dtype) -- never tensor data;
* the commit is a single atomic rename of the index ("the pointer to the
  location on stable storage is stored in a global memory");
* restore is lazy per-shard and host-remappable, so an *elastic* restart on
  a different host count re-reads exactly the shards it needs;
* content hashes detect torn/corrupt writes (the paper invokes MESI for its
  shared counters; a content-addressed single-writer index needs no
  coherence protocol).

Async mode overlaps serialization with compute and only the pointer flip is
synchronous -- the training analogue of "synchronized light-weight
checkpoints".
"""
from __future__ import annotations

import hashlib
import json
import os
import threading

import jax
import numpy as np

__all__ = ["CheckpointStore"]


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


class CheckpointStore:
    """File-backed pointer checkpoint store."""

    def __init__(self, root: str, *, n_hosts: int = 1):
        self.root = root
        self.n_hosts = n_hosts
        os.makedirs(root, exist_ok=True)
        self._async_thread: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, "INDEX.json")

    def _host_dir(self, host: int, step: int) -> str:
        d = os.path.join(self.root, f"host_{host:03d}", f"step_{step:09d}")
        os.makedirs(d, exist_ok=True)
        return d

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             sync: bool = True) -> dict:
        """Write shards + commit the pointer index.  ``tree`` is any pytree
        of arrays; leaves are round-robined across hosts (stand-in for "each
        host writes its local shards")."""
        self.wait()
        leaves, _ = _leaf_paths(tree)

        def _write() -> dict:
            index = {"step": step, "extra": extra or {}, "leaves": {}}
            for i, (name, leaf) in enumerate(leaves):
                host = i % self.n_hosts
                arr = np.asarray(leaf)
                fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
                fpath = os.path.join(self._host_dir(host, step), fname)
                with open(fpath, "wb") as f:
                    np.save(f, arr)
                digest = hashlib.sha1(arr.tobytes()).hexdigest()
                index["leaves"][name] = {
                    "host": host, "file": fpath, "sha1": digest,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
            tmp = self._index_path() + f".tmp{step}"
            with open(tmp, "w") as f:
                json.dump(index, f)
            os.replace(tmp, self._index_path())   # atomic pointer flip
            return index

        if sync:
            return _write()
        self._async_thread = threading.Thread(target=_write, daemon=True)
        self._async_thread.start()
        return {"step": step, "async": True}

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- restore ---------------------------------------------------------------
    def latest_step(self) -> int | None:
        self.wait()
        if not os.path.exists(self._index_path()):
            return None
        with open(self._index_path()) as f:
            return json.load(f)["step"]

    def restore(self, like_tree, *, verify: bool = True):
        """Restore into the structure of ``like_tree`` (lazy per-leaf reads).
        Returns (tree, step, extra)."""
        self.wait()
        with open(self._index_path()) as f:
            index = json.load(f)
        leaves, treedef = _leaf_paths(like_tree)
        out = []
        for name, leaf in leaves:
            meta = index["leaves"][name]
            with open(meta["file"], "rb") as f:
                arr = np.load(f)
            if verify:
                digest = hashlib.sha1(arr.tobytes()).hexdigest()
                if digest != meta["sha1"]:
                    raise IOError(f"checksum mismatch for {name} "
                                  f"({meta['file']})")
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, index["step"], index["extra"]
