"""Light-weight pointer-based distributed checkpointing.

The paper's checkpoint design (Section 3.1.3 / 4.1) adapted to training:

* every host dumps only *its own shards* to host-local stable storage
  (``store_dir/host_XX/step_N/leaf.npy``);
* a tiny **global index** (JSON) holds only *pointers* -- leaf path ->
  (host, file, content hash, shape, dtype) -- never tensor data;
* the commit is a single atomic rename of the index ("the pointer to the
  location on stable storage is stored in a global memory");
* restore is lazy per-shard and host-remappable, so an *elastic* restart on
  a different host count re-reads exactly the shards it needs;
* content hashes detect torn/corrupt writes (the paper invokes MESI for its
  shared counters; a content-addressed single-writer index needs no
  coherence protocol).

Robustness (the ``repro.chaos`` ``ckpt_corrupt`` recovery path):

* the store retains the last ``keep`` committed indices (older indices and
  their shard directories are pruned after each commit);
* ``restore`` walks committed indices newest -> oldest and returns the
  newest checkpoint whose shards *all* verify; a shard that fails its
  content hash (or is missing/unreadable) is **quarantined** — moved to
  ``store_dir/quarantine/`` with a JSON-logged reason — and the failed
  index is retired so later restores skip it.  Only when every committed
  checkpoint fails does ``restore`` raise.
* async-save failures are never silent: an exception raised inside the
  daemon ``_write`` thread is captured and re-raised from :meth:`wait`
  (and therefore from the next :meth:`save`/:meth:`restore`), instead of
  leaving a stale pointer with no signal.

Disk-full resilience (the ``repro.chaos`` ``disk_full`` recovery path):

* when a shard write raises ENOSPC mid-save (organically, or injected via
  :meth:`inject_disk_full`), the store deletes the half-written shards of
  the failed attempt, **prunes its oldest committed checkpoint** (index
  first, then shards) to free space, and retries the save;
* only when no committed history is left to prune does the error propagate;
* the committed index can never be corrupted by this path: the pointer flip
  is a single atomic rename that only happens after every shard of the
  attempt has been written, and :meth:`verify_committed` can audit that
  every committed index still points at verifying shards.

Async mode overlaps serialization with compute and only the pointer flip is
synchronous -- the training analogue of "synchronized light-weight
checkpoints".
"""
from __future__ import annotations

import errno
import glob
import hashlib
import json
import logging
import os
import shutil
import threading

import jax
import numpy as np

from repro.obs.trace import NULL_TRACER

__all__ = ["CheckpointStore"]

log = logging.getLogger(__name__)


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


class CheckpointStore:
    """File-backed pointer checkpoint store with fallback restore."""

    def __init__(self, root: str, *, n_hosts: int = 1, keep: int = 3,
                 tracer=None):
        self.root = root
        self.n_hosts = n_hosts
        self.keep = max(1, int(keep))
        self.tracer = tracer if tracer is not None else NULL_TRACER
        os.makedirs(root, exist_ok=True)
        self._async_thread: threading.Thread | None = None
        self._async_exc: BaseException | None = None
        self.quarantined: list[dict] = []
        # committed indices skipped during the most recent restore()
        self.last_restore_fallbacks = 0
        # disk-full path: armed ENOSPC injections + recovery counters
        self._enospc_armed = 0
        self.enospc_retries = 0
        self.pruned_for_space: list[int] = []

    # -- paths ---------------------------------------------------------------
    def _index_path(self, step: int) -> str:
        return os.path.join(self.root, f"index_{step:09d}.json")

    def _host_dir(self, host: int, step: int) -> str:
        d = os.path.join(self.root, f"host_{host:03d}", f"step_{step:09d}")
        os.makedirs(d, exist_ok=True)
        return d

    def _quarantine_dir(self) -> str:
        d = os.path.join(self.root, "quarantine")
        os.makedirs(d, exist_ok=True)
        return d

    # -- committed-index bookkeeping -----------------------------------------
    def _list_committed(self) -> list[int]:
        steps = []
        for f in os.listdir(self.root):
            if f.startswith("index_") and f.endswith(".json"):
                try:
                    steps.append(int(f[len("index_"):-len(".json")]))
                except ValueError:
                    pass
        return sorted(steps)

    def committed_steps(self) -> list[int]:
        """Steps with a committed index, oldest first."""
        self.wait()
        return self._list_committed()

    def read_index(self, step: int) -> dict:
        with open(self._index_path(step)) as f:
            return json.load(f)

    def _prune(self) -> None:
        # index first: a crash mid-prune must never leave an index pointing
        # at deleted shards
        for step in self._list_committed()[:-self.keep]:
            try:
                os.remove(self._index_path(step))
            except OSError:
                pass
            for d in glob.glob(os.path.join(
                    self.root, "host_*", f"step_{step:09d}")):
                shutil.rmtree(d, ignore_errors=True)

    # -- disk-full (ENOSPC) handling ------------------------------------------
    def inject_disk_full(self, count: int = 1) -> None:
        """Arm the next ``count`` shard-write attempts to raise ENOSPC
        mid-save (the ``repro.chaos`` ``disk_full`` fault)."""
        self._enospc_armed += max(0, int(count))

    def _drop_step_files(self, step: int) -> None:
        """Delete the (possibly half-written) shards of an uncommitted
        attempt; never touches the committed index."""
        for d in glob.glob(os.path.join(
                self.root, "host_*", f"step_{step:09d}")):
            shutil.rmtree(d, ignore_errors=True)

    def _prune_oldest_for_space(self, protect: int) -> bool:
        """Free space by retiring the oldest committed checkpoint (index
        first, then shards).  ``protect`` is the step being written — its
        predecessor history is fair game, the in-flight step is not."""
        candidates = [s for s in self._list_committed() if s != protect]
        if not candidates:
            return False
        victim = candidates[0]
        try:
            os.remove(self._index_path(victim))
        except OSError:
            pass
        self._drop_step_files(victim)
        self.pruned_for_space.append(victim)
        self.tracer.event("ckpt.prune", step=victim, reason="disk_full")
        log.warning("checkpoint step %d pruned to free disk space", victim)
        return True

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             sync: bool = True) -> dict:
        """Write shards + commit the pointer index.  ``tree`` is any pytree
        of arrays; leaves are round-robined across hosts (stand-in for "each
        host writes its local shards").

        A shard write that raises ENOSPC aborts the attempt *before* the
        pointer flip: the half-written shards are deleted, the oldest
        committed checkpoint is pruned to free space, and the save retries.
        The error propagates only when no committed history remains to
        prune, and the committed index is consistent either way."""
        self.wait()
        leaves, _ = _leaf_paths(tree)

        def _write_once() -> dict:
            index = {"step": step, "extra": extra or {}, "leaves": {}}
            for i, (name, leaf) in enumerate(leaves):
                host = i % self.n_hosts
                arr = np.asarray(leaf)
                fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
                fpath = os.path.join(self._host_dir(host, step), fname)
                if self._enospc_armed and i >= len(leaves) // 2:
                    self._enospc_armed -= 1
                    raise OSError(errno.ENOSPC,
                                  "No space left on device (injected)",
                                  fpath)
                with open(fpath, "wb") as f:
                    np.save(f, arr)
                digest = hashlib.sha1(arr.tobytes()).hexdigest()
                index["leaves"][name] = {
                    "host": host, "file": fpath, "sha1": digest,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
            tmp = self._index_path(step) + ".tmp"
            with open(tmp, "w") as f:
                json.dump(index, f)
            os.replace(tmp, self._index_path(step))   # atomic pointer flip
            self._prune()
            return index

        def _write() -> dict:
            while True:
                try:
                    return _write_once()
                except OSError as e:
                    if e.errno != errno.ENOSPC:
                        raise
                    self._drop_step_files(step)
                    if not self._prune_oldest_for_space(step):
                        raise
                    self.enospc_retries += 1
                    self.tracer.event("ckpt.enospc_retry", step=step)
                    log.warning("checkpoint save step %d hit ENOSPC; "
                                "pruned oldest commit and retrying", step)

        if sync:
            with self.tracer.span("ckpt.save", track="ckpt-io", step=step,
                                  mode="sync"):
                return _write()

        def _runner() -> None:
            t0 = self.tracer.clock()
            try:
                _write()
                # complete() is thread-safe (bypasses the span stack), so
                # the writer thread can report its own wall time
                self.tracer.complete("ckpt.save", t0, self.tracer.clock(),
                                     track="ckpt-io", step=step,
                                     mode="async")
            except BaseException as e:   # surfaced from wait(), not lost
                self._async_exc = e

        self._async_thread = threading.Thread(target=_runner, daemon=True)
        self._async_thread.start()
        return {"step": step, "async": True}

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_exc is not None:
            exc, self._async_exc = self._async_exc, None
            raise exc

    # -- restore ---------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def _quarantine(self, path: str, reason: str, step: int) -> None:
        qdir = self._quarantine_dir()
        dest = os.path.join(qdir, f"step_{step:09d}__{os.path.basename(path)}")
        try:
            os.replace(path, dest)
        except OSError:
            dest = None
        rec = {"step": step, "path": path, "quarantined_to": dest,
               "reason": reason}
        self.quarantined.append(rec)
        self.tracer.event("ckpt.quarantine", step=step, reason=reason)
        with open(os.path.join(qdir, "LOG.jsonl"), "a") as f:
            f.write(json.dumps(rec) + "\n")
        log.warning("checkpoint shard quarantined: %s (%s)", path, reason)

    def _read_verified(self, step: int, leaves, verify: bool):
        index = self.read_index(step)
        out = []
        for name, _ in leaves:
            meta = index["leaves"].get(name)
            if meta is None:
                raise IOError(f"leaf {name} missing from index step {step}")
            with open(meta["file"], "rb") as f:
                arr = np.load(f)
            if verify:
                digest = hashlib.sha1(arr.tobytes()).hexdigest()
                if digest != meta["sha1"]:
                    self._quarantine(meta["file"],
                                     f"checksum mismatch for leaf {name}",
                                     step)
                    raise IOError(f"checksum mismatch for {name} "
                                  f"({meta['file']})")
            out.append(arr)
        return out, index

    def restore(self, like_tree, *, verify: bool = True):
        """Restore into the structure of ``like_tree`` (lazy per-leaf reads).

        Walks committed checkpoints newest -> oldest and returns the newest
        one whose shards all verify, quarantining bad shards and retiring
        failed indices along the way.  Raises only when *no* committed
        checkpoint passes.  Returns (tree, step, extra).
        """
        self.wait()
        leaves, treedef = _leaf_paths(like_tree)
        steps = self.committed_steps()
        if not steps:
            raise FileNotFoundError(
                f"no committed checkpoint index under {self.root}")
        self.last_restore_fallbacks = 0
        errors: list[str] = []
        with self.tracer.span("ckpt.restore", track="ckpt-io",
                              newest=steps[-1]) as sp:
            for step in reversed(steps):
                try:
                    out, index = self._read_verified(step, leaves, verify)
                except Exception as e:   # corrupt/missing shard: fall back
                    errors.append(f"step {step}: {e}")
                    self.last_restore_fallbacks += 1
                    self.tracer.event("ckpt.fallback", step=step,
                                      reason=str(e)[:120])
                    # retire the failed index so later restores skip it
                    try:
                        os.replace(self._index_path(step), os.path.join(
                            self._quarantine_dir(), f"index_{step:09d}.json"))
                    except OSError:
                        pass
                    log.warning("checkpoint step %d failed verification "
                                "(%s); falling back", step, e)
                    continue
                if errors:
                    log.warning("restore fell back to step %d after %d bad "
                                "checkpoint(s)", step, len(errors))
                    self.tracer.recovery("ckpt_corrupt", restored_step=step,
                                         fallbacks=len(errors))
                sp.set(restored_step=step,
                       fallbacks=self.last_restore_fallbacks)
                tree = jax.tree_util.tree_unflatten(treedef, out)
                return tree, index["step"], index["extra"]
        raise IOError(
            f"no committed checkpoint passed verification under {self.root} "
            f"(bad shards quarantined to {self._quarantine_dir()}): "
            + "; ".join(errors))

    def verify_committed(self) -> list[str]:
        """Audit every committed index: each must parse and every shard it
        points at must exist and match its content hash.  Returns the list
        of violations (empty = the committed index is fully consistent) —
        the ``disk_full`` invariant check."""
        problems: list[str] = []
        for step in self.committed_steps():
            try:
                index = self.read_index(step)
            except (OSError, ValueError) as e:
                problems.append(f"step {step}: unreadable index ({e})")
                continue
            for name, meta in sorted(index["leaves"].items()):
                try:
                    with open(meta["file"], "rb") as f:
                        arr = np.load(f)
                except OSError as e:
                    problems.append(f"step {step}: shard {name} missing "
                                    f"({e})")
                    continue
                if hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
                    problems.append(
                        f"step {step}: shard {name} checksum mismatch")
        return problems
