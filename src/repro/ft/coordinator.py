"""Fault-tolerant training coordinator (checkpoint / restart / elastic).

Runs the jit'd train step under simulated host failures:

* a :class:`FaultInjector` (Weibull MTBF / log-normal MTTR, the paper's
  Section 4.1 distributions) decides which steps are interrupted;
* on failure the coordinator restores params/opt/data-iterator from the
  :class:`~repro.ft.checkpoint.CheckpointStore` pointer index and replays
  from the last checkpoint -- work since then is the "beyond last
  checkpoint" waste the paper measures;
* the checkpoint cadence follows :class:`~repro.ft.interval.DynamicInterval`
  (Lemma 3.1: unstable environments checkpoint more often);
* ``on_rescale`` supports *elastic* restarts: the pointer index is
  host-count-agnostic, so a restore onto fewer hosts re-shards transparently
  (demonstrated in tests with a re-built data pipeline / step function).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from .checkpoint import CheckpointStore
from .interval import DynamicInterval

__all__ = ["FaultInjector", "TrainingCoordinator", "CoordinatorReport"]


class FaultInjector:
    """Samples failure steps from Weibull MTBF (in units of steps)."""

    def __init__(self, *, mtbf_steps: float, shape: float = 12.0,
                 mttr_steps: float = 2.0, seed: int = 0,
                 horizon_steps: int = 100_000):
        rng = np.random.default_rng(seed)
        self.fail_steps: set[int] = set()
        self.mttr_steps = mttr_steps
        t = rng.uniform(0, mtbf_steps)
        while t < horizon_steps:
            self.fail_steps.add(int(t))
            t += max(1.0, mtbf_steps * rng.weibull(shape))

    def fails_at(self, step: int) -> bool:
        return step in self.fail_steps

    def consume(self, step: int) -> bool:
        """Pop the failure scheduled at ``step`` (True if one fired)."""
        if step in self.fail_steps:
            self.fail_steps.discard(step)
            return True
        return False

    def defer(self, step: int, to_step: int) -> None:
        """Move a failure scheduled at ``step`` to ``to_step``.

        Used when the target is already down at ``step``: the fault is not
        silently absorbed by the outage — it strikes again the moment the
        target is back up (``to_step`` = repair completion).
        """
        if to_step > step and step in self.fail_steps:
            self.fail_steps.discard(step)
            self.fail_steps.add(int(to_step))


@dataclasses.dataclass
class CoordinatorReport:
    steps_completed: int
    failures: int
    restores: int
    wasted_steps: int
    checkpoints: int
    final_loss: float
    losses: list


class TrainingCoordinator:
    def __init__(self, *, train_step: Callable, params, opt_state,
                 pipeline, store: CheckpointStore,
                 interval: DynamicInterval | None = None,
                 step_time_s: float = 1.0,
                 injector: FaultInjector | None = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.store = store
        self.interval = interval or DynamicInterval(gamma_s=1.0)
        self.step_time_s = step_time_s
        self.injector = injector
        self.step = 0
        self._last_ckpt_step = -1

    # -- checkpoint cadence in steps -----------------------------------------
    def _ckpt_every(self) -> int:
        lam = self.interval.current_lambda()
        return max(1, int(round(lam / self.step_time_s)))

    def _save(self, *, sync: bool) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        self.store.save(self.step, tree, extra=self.pipeline.state(),
                        sync=sync)
        self._last_ckpt_step = self.step

    def _restore(self) -> None:
        tree, step, extra = self.store.restore(
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.pipeline = type(self.pipeline).from_state(
            self.pipeline.cfg, self.pipeline.model_cfg, extra)
        self.step = step

    # -- main loop --------------------------------------------------------------
    def run(self, n_steps: int) -> CoordinatorReport:
        failures = restores = wasted = ckpts = 0
        losses: list[float] = []
        self._save(sync=True)
        ckpts += 1
        virtual_t = 0.0
        while self.step < n_steps:
            if self.injector is not None and self.injector.consume(self.step):
                # host failure mid-step: lose work since last checkpoint
                failures += 1
                wasted += self.step - self._last_ckpt_step
                self.interval.record_failure(virtual_t)
                self.interval.record_repair(
                    self.injector.mttr_steps * self.step_time_s)
                virtual_t += self.injector.mttr_steps * self.step_time_s
                self._restore()
                restores += 1
                continue
            batch = self.pipeline.batch_at(self.pipeline.next_index)
            self.pipeline.next_index += 1
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            losses.append(float(metrics["loss"]))
            self.step += 1
            virtual_t += self.step_time_s
            if self.step - self._last_ckpt_step >= self._ckpt_every():
                self._save(sync=False)   # async: only the pointer flip syncs
                ckpts += 1
        self.store.wait()
        return CoordinatorReport(
            steps_completed=self.step, failures=failures, restores=restores,
            wasted_steps=wasted, checkpoints=ckpts,
            final_loss=losses[-1] if losses else float("nan"), losses=losses)
