"""Fault-tolerant training coordinator (checkpoint / restart / elastic).

Runs the jit'd train step under simulated host failures:

* a :class:`FaultInjector` (Weibull MTBF / log-normal MTTR, the paper's
  Section 4.1 distributions) decides which steps are interrupted;
* on failure the coordinator restores params/opt/data-iterator from the
  :class:`~repro.ft.checkpoint.CheckpointStore` pointer index and replays
  from the last checkpoint -- work since then is the "beyond last
  checkpoint" waste the paper measures;
* the checkpoint cadence follows :class:`~repro.ft.interval.DynamicInterval`
  (Lemma 3.1: unstable environments checkpoint more often);
* ``on_rescale`` supports *elastic* restarts: the pointer index is
  host-count-agnostic, so a restore onto fewer hosts re-shards transparently
  (demonstrated in tests with a re-built data pipeline / step function).

Chaos hardening (the ``repro.chaos`` training-side recovery paths):

* **NaN/Inf guard** — a non-finite loss (organic or injected via the
  ``nan_poison`` fault) *rejects* the already-computed update, rolls the
  in-memory params/opt back to their pre-step values, and quarantines the
  poisoned batch index so checkpoint replay skips it too;
* **escalating backoff** — when the same step fails repeatedly (the
  multiset :class:`FaultInjector` schedule can hold several faults on one
  step), the simulated repair wait doubles per repeat and a synchronous
  checkpoint is forced immediately before the retry, bounding replay waste;
* a ``ckpt_corrupt`` fault flips bytes in the newest committed checkpoint
  shard; the subsequent restore transparently falls back to the newest
  checkpoint that verifies (``CheckpointStore`` quarantine path);
* a ``slowdown`` fault costs virtual time (a straggler) but loses no state;
* a ``net_partition`` fault on the single-actor coordinator is the
  degenerate one-pod cluster case: no quorum exists, so the whole cluster
  *parks* for the partition window (virtual time lost, no state) — the real
  quorum/minority split lives in ``repro.ft.crosspod.PodTrainingCluster``;
* a ``disk_full`` fault arms the store's next save with a mid-write ENOSPC
  and forces a checkpoint through it: the store prunes its oldest commit
  and retries, and the committed index stays consistent
  (``CheckpointStore.verify_committed``).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable

import numpy as np

from repro.chaos.faults import (CAPACITY_LOSS, CKPT_CORRUPT, DISK_FULL,
                                HOST_CRASH, NAN_POISON, NET_PARTITION,
                                SLOWDOWN, corrupt_checkpoint_shard)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER

from .checkpoint import CheckpointStore
from .interval import DynamicInterval

__all__ = ["FaultInjector", "TrainingCoordinator", "CoordinatorReport"]


class FaultInjector:
    """Samples failure steps from Weibull MTBF (in units of steps).

    The schedule is a step -> count **multiset** (`collections.Counter`):
    two faults scheduled — or deferred — onto the same step remain two
    distinct faults and strike on consecutive visits, instead of silently
    collapsing into one as a plain set would.
    """

    def __init__(self, *, mtbf_steps: float, shape: float = 12.0,
                 mttr_steps: float = 2.0, seed: int = 0,
                 horizon_steps: int = 100_000):
        rng = np.random.default_rng(seed)
        self._schedule: collections.Counter = collections.Counter()
        self.mttr_steps = mttr_steps
        t = rng.uniform(0, mtbf_steps)
        while t < horizon_steps:
            self._schedule[int(t)] += 1
            t += max(1.0, mtbf_steps * rng.weibull(shape))

    @property
    def fail_steps(self) -> collections.Counter:
        """step -> scheduled-fault count (supports ``in`` / iteration)."""
        return self._schedule

    @fail_steps.setter
    def fail_steps(self, steps) -> None:
        # accepts a set/iterable (each step once) or a mapping step -> count
        self._schedule = collections.Counter(steps)

    def fails_at(self, step: int) -> bool:
        return self._schedule[step] > 0

    def consume(self, step: int) -> bool:
        """Pop one failure scheduled at ``step`` (True if one fired)."""
        if self._schedule[step] > 0:
            self._schedule[step] -= 1
            if not self._schedule[step]:
                del self._schedule[step]
            return True
        return False

    def defer(self, step: int, to_step: int) -> None:
        """Move one failure scheduled at ``step`` to ``to_step``.

        Used when the target is already down at ``step``: the fault is not
        silently absorbed by the outage — it strikes again the moment the
        target is back up (``to_step`` = repair completion).  Deferring onto
        a step that already holds a fault stacks them (multiset), so two
        deferred faults fire on two separate visits.
        """
        if to_step > step and self._schedule[step] > 0:
            self._schedule[step] -= 1
            if not self._schedule[step]:
                del self._schedule[step]
            self._schedule[int(to_step)] += 1


@dataclasses.dataclass
class CoordinatorReport:
    steps_completed: int
    failures: int
    restores: int
    wasted_steps: int
    checkpoints: int
    final_loss: float
    losses: list
    nan_rollbacks: int = 0       # NaN/Inf updates rejected by the guard
    skipped_batches: int = 0     # poisoned batch indices quarantined
    backoff_steps: float = 0.0   # extra repair wait from escalation
    ckpt_fallbacks: int = 0      # restores that skipped a corrupt checkpoint
    ckpt_corruptions: int = 0    # injected ckpt_corrupt events applied
    slowdowns: int = 0           # straggler events absorbed
    partitions: int = 0          # net_partition windows parked through
    parked_steps: float = 0.0    # virtual steps lost to partition parking
    disk_full_events: int = 0    # injected ENOSPC saves
    enospc_retries: int = 0      # saves that pruned-and-retried past ENOSPC
    index_violations: int = 0    # committed-index audit failures (must be 0)


class TrainingCoordinator:
    def __init__(self, *, train_step: Callable, params, opt_state,
                 pipeline, store: CheckpointStore,
                 interval: DynamicInterval | None = None,
                 step_time_s: float = 1.0,
                 injector: FaultInjector | None = None,
                 chaos=None, tracer=None,
                 registry: MetricsRegistry | None = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.store = store
        self.interval = interval or DynamicInterval(gamma_s=1.0)
        self.step_time_s = step_time_s
        self.injector = injector
        self.chaos = chaos   # repro.chaos.ChaosEngine | None
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.registry = registry if registry is not None else MetricsRegistry()
        # the coordinator's former inline counters, as labeled series; the
        # report reads these back, so a shared registry sees the same numbers
        self._ev = self.registry.counter(
            "train_events_total",
            "training-side fault/recovery events by kind", ("kind",))
        self._ckpt_count = self.registry.counter(
            "train_checkpoints_total", "checkpoints committed by mode",
            ("mode",))
        self._wasted = self.registry.counter(
            "train_wasted_steps_total",
            "steps replayed because they were past the last checkpoint")
        self._lost = self.registry.counter(
            "train_lost_steps_total",
            "virtual steps lost without state loss, by cause", ("cause",))
        self.step = 0
        self._last_ckpt_step = -1
        self._nan_skip: set[int] = set()         # quarantined batch indices
        self._fail_counts: collections.Counter = collections.Counter()
        self._ckpt_before: set[int] = set()      # pre-retry barrier steps

    # -- checkpoint cadence in steps -----------------------------------------
    def _ckpt_every(self) -> int:
        lam = self.interval.current_lambda()
        return max(1, int(round(lam / self.step_time_s)))

    def _save(self, *, sync: bool) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        self.store.save(self.step, tree, extra=self.pipeline.state(),
                        sync=sync)
        self._last_ckpt_step = self.step
        self._ckpt_count.inc(mode="sync" if sync else "async")

    def _restore(self) -> None:
        tree, step, extra = self.store.restore(
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.pipeline = type(self.pipeline).from_state(
            self.pipeline.cfg, self.pipeline.model_cfg, extra)
        self.step = step
        # the restored checkpoint IS the last good checkpoint (a fallback
        # restore may land earlier than the newest save)
        self._last_ckpt_step = step

    # -- main loop --------------------------------------------------------------
    def run(self, n_steps: int) -> CoordinatorReport:
        ev, lost = self._ev, self._lost
        losses: list[float] = []
        self._save(sync=True)
        virtual_t = 0.0
        while self.step < n_steps:
            step = self.step
            if step in self._ckpt_before and self._last_ckpt_step < step:
                # a previous visit to this step failed repeatedly: checkpoint
                # right before the retry so a re-strike replays nothing
                self._save(sync=True)
            # -- faults scheduled for this step ------------------------------
            crash = False
            poison = False
            repair = float(self.injector.mttr_steps
                           if self.injector is not None else 2.0)
            if self.chaos is not None:
                for ev_ in self.chaos.events_at(step):
                    if ev_.kind in (HOST_CRASH, CAPACITY_LOSS):
                        crash = True
                        repair = max(repair, float(ev_.duration))
                    elif ev_.kind == SLOWDOWN:
                        ev.inc(kind="slowdown")
                        lost.inc(ev_.duration, cause="slowdown")
                        virtual_t += ev_.duration * self.step_time_s
                    elif ev_.kind == CKPT_CORRUPT:
                        if corrupt_checkpoint_shard(self.store, ev_.seed):
                            ev.inc(kind="ckpt_corrupt")
                    elif ev_.kind == NAN_POISON:
                        poison = True
                    elif ev_.kind == NET_PARTITION:
                        # degenerate single-pod cluster: no quorum on the
                        # other side of the cut -> whole-cluster park for
                        # the window (wall clock lost, no state lost)
                        ev.inc(kind="net_partition")
                        lost.inc(ev_.duration, cause="partition_park")
                        virtual_t += ev_.duration * self.step_time_s
                        self.tracer.recovery("net_partition", step=step,
                                             parked=ev_.duration)
                    elif ev_.kind == DISK_FULL:
                        # arm the next save with a mid-write ENOSPC and
                        # push a checkpoint through it immediately: the
                        # store must prune-and-retry, never corrupt the
                        # committed index
                        self.store.inject_disk_full()
                        ev.inc(kind="disk_full")
                        retries_before = self.store.enospc_retries
                        self._save(sync=False)
                        self.store.wait()
                        self.tracer.recovery(
                            "disk_full", step=step,
                            retries=self.store.enospc_retries
                            - retries_before)
            if self.injector is not None and self.injector.consume(step):
                crash = True
            if crash:
                # host failure mid-step: lose work since last checkpoint
                ev.inc(kind="failure")
                self._wasted.inc(step - self._last_ckpt_step)
                self._fail_counts[step] += 1
                streak = self._fail_counts[step]
                backoff = repair * (2 ** (streak - 1))   # escalate on repeat
                if backoff > repair:
                    lost.inc(backoff - repair, cause="backoff")
                    self.tracer.event("coord.backoff", step=step,
                                      streak=streak, wait=backoff)
                if streak >= 2:
                    self._ckpt_before.add(step)
                self.interval.record_failure(virtual_t)
                self.interval.record_repair(backoff * self.step_time_s)
                virtual_t += backoff * self.step_time_s
                self._restore()
                ev.inc(self.store.last_restore_fallbacks,
                       kind="ckpt_fallback")
                ev.inc(kind="restore")
                self.tracer.recovery(
                    "host_crash", step=step, restored_step=self.step,
                    wasted=step - self._last_ckpt_step)
                continue
            # -- one train step (skipping quarantined batches) ---------------
            while self.pipeline.next_index in self._nan_skip:
                self.pipeline.next_index += 1
            bidx = self.pipeline.next_index
            batch = self.pipeline.batch_at(bidx)
            self.pipeline.next_index += 1
            params, opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            if poison:
                loss = float("nan")   # injected: poisoned train-step output
            if not math.isfinite(loss):
                # NaN/Inf guard: reject the update (params/opt keep their
                # pre-step values) and quarantine the batch so checkpoint
                # replay skips it too
                ev.inc(kind="nan_rollback")
                ev.inc(kind="batch_quarantined")
                self._nan_skip.add(bidx)
                self.tracer.recovery("nan_poison", step=step, batch=bidx)
                continue
            self.params, self.opt_state = params, opt_state
            losses.append(loss)
            self.step += 1
            virtual_t += self.step_time_s
            if self.step - self._last_ckpt_step >= self._ckpt_every():
                self._save(sync=False)   # async: only the pointer flip syncs
        self.store.wait()
        return CoordinatorReport(
            steps_completed=self.step,
            failures=int(ev.value(kind="failure")),
            restores=int(ev.value(kind="restore")),
            wasted_steps=int(self._wasted.total()),
            checkpoints=int(self._ckpt_count.total()),
            final_loss=losses[-1] if losses else float("nan"), losses=losses,
            nan_rollbacks=int(ev.value(kind="nan_rollback")),
            skipped_batches=int(ev.value(kind="batch_quarantined")),
            backoff_steps=float(lost.value(cause="backoff")),
            ckpt_fallbacks=int(ev.value(kind="ckpt_fallback")),
            ckpt_corruptions=int(ev.value(kind="ckpt_corrupt")),
            slowdowns=int(ev.value(kind="slowdown")),
            partitions=int(ev.value(kind="net_partition")),
            parked_steps=float(lost.value(cause="partition_park")),
            disk_full_events=int(ev.value(kind="disk_full")),
            enospc_retries=self.store.enospc_retries,
            index_violations=len(self.store.verify_committed()))
