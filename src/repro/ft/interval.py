"""Dynamic checkpoint interval from online failure statistics.

The paper's Lemma 3.1 shows lambda* is environment dependent: we estimate
the environment *online* -- Weibull MTBF via moment matching on observed
inter-failure gaps, log-normal MTTR from repair durations -- and re-derive
lambda* as failures accumulate.  The closed-form first-order optimum is the
Young/Daly interval sqrt(2 * gamma * MTBF); the full Lemma-3.1 model (which
adds the resubmission/waiting terms) is available through
``repro.core.checkpoint_policy`` when a schedule is in hand.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = ["DynamicInterval"]


class DynamicInterval:
    def __init__(self, *, gamma_s: float, lam_min: float = 10.0,
                 lam_max: float = 3600.0, prior_mtbf_s: float = 4 * 3600.0):
        self.gamma_s = float(gamma_s)
        self.lam_min, self.lam_max = lam_min, lam_max
        self.prior_mtbf_s = prior_mtbf_s
        self.failure_times: list[float] = []
        self.repair_durations: list[float] = []

    # -- observations ---------------------------------------------------------
    def record_failure(self, t: float) -> None:
        self.failure_times.append(float(t))

    def record_repair(self, duration_s: float) -> None:
        self.repair_durations.append(float(duration_s))

    # -- estimates --------------------------------------------------------------
    def mtbf(self) -> float:
        if len(self.failure_times) < 2:
            return self.prior_mtbf_s
        gaps = np.diff(sorted(self.failure_times))
        gaps = gaps[gaps > 0]
        if gaps.size == 0:
            return self.prior_mtbf_s
        # Weibull moment match: with the paper's shapes (11.5-12.5) the mean
        # ~= scale, so the empirical mean is the MTBF estimate; blend with
        # the prior while the sample is small.
        w = min(1.0, gaps.size / 8.0)
        return float(w * gaps.mean() + (1 - w) * self.prior_mtbf_s)

    def mttr(self) -> float:
        if not self.repair_durations:
            return 60.0
        logs = np.log(np.maximum(self.repair_durations, 1e-3))
        return float(np.exp(logs.mean() + 0.5 * logs.var()))

    def current_lambda(self) -> float:
        """Young/Daly first-order optimum, clamped."""
        lam = math.sqrt(2.0 * self.gamma_s * self.mtbf())
        return float(min(max(lam, self.lam_min), self.lam_max))
