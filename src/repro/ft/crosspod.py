"""Partition-tolerant compressed cross-pod gradient exchange.

Inside a pod, gradients reduce over ICI in bf16 (the jit'd step).  *Across*
pods the DCN link is ~20x slower, so the pod-level reduction sends int8
gradients with per-tensor scales and error feedback (repro.optim.
grad_compression): 4x fewer DCN bytes than fp32 with a bias that vanishes
over steps.  On real hardware the exchange maps 1:1 onto a DCN allgather of
the int8 payloads.

The DCN is also the part of the fabric that actually *fails*: this module
models that with a link-reachability matrix over pods.  A ``net_partition``
fault (``repro.chaos``) severs the minority pods' links, splitting the
cluster into components:

* the component holding a strict **majority** of pods (the quorum) keeps
  training on its own averaged gradients — pods run replicated
  data-parallel (every pod computes the full global batch, the paper's
  replication heuristic applied at pod granularity), so the quorum average
  *is* the full-cluster average and a 2-of-3 quorum stays exactly on the
  3-pod trajectory;
* minority pods **park**: no compute, no update, error-feedback residuals
  frozen;
* with no majority component (a tie, or everything cut) the whole cluster
  parks — two components may never both advance, which is exactly the
  split-brain failure mode;
* on **heal** the quorum commits a synchronous checkpoint (params +
  optimizer + its error-feedback residual) and every stale pod catches up
  by restoring it through :class:`~repro.ft.checkpoint.CheckpointStore`'s
  fallback-capable ``restore``; the stale pod's own residual is *reset*
  (discarded) and replaced by the quorum's checkpointed one, so
  compression bias accumulated before the partition cannot leak across it.

Split-brain is not assumed away — it is *detected*: every advancing pod
fingerprints its post-update parameters each round and
:meth:`PodGradientExchange.check_round_fingerprints` counts any round where
two advancing pods disagree.  ``--chaos-assert`` runs require that counter
to be zero.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import numpy as np

from repro.chaos.faults import DISK_FULL, NET_PARTITION
from repro.models import lm
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.grad_compression import (compress_tree_with_feedback,
                                          decompress_tree)

from .checkpoint import CheckpointStore

__all__ = ["PodGradientExchange", "ExchangeResult", "PodTrainingCluster",
           "ClusterReport", "tree_digest"]


def tree_digest(tree) -> str:
    """Order-stable sha1 over a pytree's leaf bytes (the per-round state
    fingerprint used for split-brain detection)."""
    h = hashlib.sha1()
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf)
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class ExchangeResult:
    """Outcome of one exchange round.

    ``avg`` is the averaged (decompressed) gradient tree the quorum applies,
    or ``None`` when no component holds a majority and the whole cluster
    parks.  ``fingerprint`` digests ``avg`` (the agreed update)."""

    avg: object | None
    quorum: tuple[int, ...]
    parked: tuple[int, ...]
    fingerprint: str | None


class PodGradientExchange:
    """Quorum-gated gradient exchange over an explicit link matrix."""

    def __init__(self, n_pods: int):
        self.n_pods = n_pods
        self.residuals = [None] * n_pods   # error-feedback state per pod
        self.bytes_sent_fp32 = 0
        self.bytes_sent_int8 = 0
        # link-reachability matrix: links[i, j] == the DCN path i <-> j is up
        self.links = np.ones((n_pods, n_pods), bool)
        self._cut: set[int] = set()
        self.round_no = 0
        self.parked_pod_rounds = 0
        self.split_brain_divergences = 0
        self.fingerprint_log: list[tuple[int, str]] = []

    # -- link topology --------------------------------------------------------
    def partition(self, minority) -> tuple[int, ...]:
        """Sever every link of each ``minority`` pod (conservative model:
        a cut pod is fully isolated, including from other cut pods)."""
        cut = tuple(sorted({int(p) % self.n_pods for p in minority}))
        for p in cut:
            self._cut.add(p)
            self.links[p, :] = False
            self.links[:, p] = False
            self.links[p, p] = True
        return cut

    def restore_pods(self, pods) -> None:
        """Heal: re-attach ``pods`` to every pod that is not itself cut."""
        for p in pods:
            self._cut.discard(int(p))
        for p in (int(q) for q in pods):
            for q in range(self.n_pods):
                up = q not in self._cut
                self.links[p, q] = self.links[q, p] = up
            self.links[p, p] = True

    def components(self) -> list[tuple[int, ...]]:
        """Connected components of the link matrix (BFS)."""
        seen: set[int] = set()
        out = []
        for start in range(self.n_pods):
            if start in seen:
                continue
            comp = {start}
            stack = [start]
            while stack:
                i = stack.pop()
                for j in range(self.n_pods):
                    if j not in comp and self.links[i, j]:
                        comp.add(j)
                        stack.append(j)
            seen |= comp
            out.append(tuple(sorted(comp)))
        return out

    def current_quorum(self) -> tuple[int, ...] | None:
        """The unique component holding a strict majority of pods, if any."""
        for comp in self.components():
            if 2 * len(comp) > self.n_pods:
                return comp
        return None

    # -- error-feedback residuals ---------------------------------------------
    def _init_residuals(self, pod: int, grads) -> None:
        if self.residuals[pod] is None:
            self.residuals[pod] = jax.tree.map(
                lambda g: np.zeros(g.shape, np.float32), grads)

    def reset_residual(self, pod: int) -> None:
        """Discard a pod's error-feedback state (membership change: a
        rejoining or replacement pod must not carry stale compression
        bias)."""
        if self.residuals[pod] is not None:
            self.residuals[pod] = jax.tree.map(
                lambda r: np.zeros(np.shape(r), np.float32),
                self.residuals[pod])

    def set_residual(self, pod: int, residual) -> None:
        """Adopt a residual (the quorum's checkpointed one, at catch-up)."""
        self.residuals[pod] = residual

    # -- the exchange ---------------------------------------------------------
    @staticmethod
    def _payloads_equal(a, b) -> bool:
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        return len(la) == len(lb) and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(la, lb))

    def round(self, pod_grads: list, *,
              with_fingerprint: bool = True) -> ExchangeResult:
        """One exchange round.  ``pod_grads[p]`` is pod ``p``'s gradient
        pytree (entries for parked pods may be ``None`` — they are never
        read).  Quorum pods compress-with-feedback, allgather, and average;
        everyone else parks.

        ``with_fingerprint=False`` skips the sha1 digest of the averaged
        update — ``tree_digest`` forces a device->host sync of every leaf,
        so sampled rounds (``PodTrainingCluster.fingerprint_every``) leave
        the result's ``fingerprint`` as ``None``."""
        assert len(pod_grads) == self.n_pods
        quorum = self.current_quorum()
        self.round_no += 1
        parked = tuple(p for p in range(self.n_pods)
                       if quorum is None or p not in quorum)
        self.parked_pod_rounds += len(parked)
        if quorum is None:
            return ExchangeResult(avg=None, quorum=(), parked=parked,
                                  fingerprint=None)
        payloads = []
        for p in quorum:
            g = pod_grads[p]
            self._init_residuals(p, g)
            q, s, r = compress_tree_with_feedback(g, self.residuals[p])
            self.residuals[p] = r
            payloads.append((q, s))
            for leaf in jax.tree.leaves(q):
                self.bytes_sent_int8 += leaf.size        # int8: 1 B each
                self.bytes_sent_fp32 += leaf.size * 4
        # Replicated-agreement fast path: when every member ships the same
        # bytes (replicated data-parallel with synchronized residuals), the
        # average IS that common value — independent of quorum size, which
        # is what keeps a 2-pod quorum bit-exact on the 3-pod trajectory.
        if all(self._payloads_equal(payloads[0], pl) for pl in payloads[1:]):
            avg = decompress_tree(*payloads[0])
        else:
            trees = [decompress_tree(q, s) for q, s in payloads]
            avg = jax.tree.map(lambda *xs: sum(xs) / len(xs), *trees)
        return ExchangeResult(
            avg=avg, quorum=quorum, parked=parked,
            fingerprint=tree_digest(avg) if with_fingerprint else None)

    def exchange(self, pod_grads: list):
        """Fully-connected compatibility wrapper: returns the averaged
        (decompressed) gradient tree every pod ends up with."""
        res = self.round(list(pod_grads))
        if res.avg is None:
            raise RuntimeError(
                "no quorum: the cluster is partitioned with no majority "
                "component; all pods are parked")
        return res.avg

    # -- split-brain detection ------------------------------------------------
    def check_round_fingerprints(self, rnd: int, pod_fps: dict[int, str]
                                 ) -> bool:
        """Record the advancing pods' post-update state fingerprints for one
        round.  Any disagreement is a split-brain divergence — a hard
        invariant violation under ``--chaos-assert``."""
        distinct = sorted(set(pod_fps.values()))
        if distinct:
            self.fingerprint_log.append((rnd, distinct[0]))
        if len(distinct) > 1:
            self.split_brain_divergences += 1
            return False
        return True

    @property
    def compression_ratio(self) -> float:
        return self.bytes_sent_fp32 / max(self.bytes_sent_int8, 1)


@dataclasses.dataclass
class ClusterReport:
    steps_completed: int
    rounds: int
    partitions: int
    parked_pod_rounds: int
    heals: int
    catchups: int
    checkpoints: int
    split_brain_divergences: int
    disk_full_events: int
    enospc_retries: int
    index_violations: int
    final_loss: float
    losses: list
    fingerprints_taken: int = 0
    fingerprints_skipped: int = 0


class PodTrainingCluster:
    """``n_pods`` replicated data-parallel pods training through the
    partition-tolerant exchange.

    Every pod holds its own params/optimizer copy; each round every
    reachable pod computes the *global* batch's gradients (pod-level
    replication: the shards are bit-identical anywhere, see
    ``repro.data``), the quorum averages them through the compressed
    exchange and applies AdamW, minority pods park.  ``net_partition``
    chaos events sever links for their ``duration``; at heal the quorum
    commits a synchronous checkpoint that stale pods restore (params,
    optimizer, *and* the quorum's error-feedback residual — the stale
    residual is reset so compression bias cannot leak across the
    partition).  ``disk_full`` events arm the shared
    :class:`~repro.ft.checkpoint.CheckpointStore` with a mid-save ENOSPC.

    Two time axes: *rounds* are wall clock (chaos events fire on them);
    *applied steps* count committed updates and index the data pipeline, so
    a whole-cluster park consumes wall clock but never skips a batch — a
    partitioned-then-healed run lands on the exact batch sequence of a
    fault-free run at equal step count.
    """

    def __init__(self, *, cfg, params, pipeline, store: CheckpointStore,
                 n_pods: int = 3, opt_cfg: AdamWConfig | None = None,
                 q_chunk: int = 16, xent_chunk: int = 16,
                 ckpt_every: int = 4, chaos=None,
                 fingerprint_every: int = 1, tracer=None,
                 registry: MetricsRegistry | None = None):
        self.cfg = cfg
        self.n_pods = n_pods
        self.pipeline = pipeline
        self.store = store
        self.chaos = chaos   # repro.chaos.ChaosEngine | None
        self.ckpt_every = max(1, int(ckpt_every))
        # split-brain fingerprints sample every N applied steps; 1 = every
        # step (the --chaos-assert setting).  tree_digest syncs every param
        # leaf to host, so sampling is the steady-state default upstream.
        self.fingerprint_every = max(1, int(fingerprint_every))
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.registry = registry if registry is not None else MetricsRegistry()
        self._fp = self.registry.counter(
            "crosspod_fingerprints_total",
            "split-brain fingerprint rounds by status (taken vs sampled "
            "away)", ("status",))
        opt_cfg = opt_cfg or AdamWConfig(lr=1e-3)

        def loss_fn(p, batch):
            loss, metrics = lm.forward_train(p, cfg, batch, q_chunk=q_chunk,
                                             xent_chunk=xent_chunk)
            return loss, metrics

        self._grad = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        self._apply = jax.jit(functools.partial(adamw_update, opt_cfg))
        self.params = [params for _ in range(n_pods)]
        self.opt = [adamw_init(params) for _ in range(n_pods)]
        self.exchange = PodGradientExchange(n_pods)
        resid0 = jax.tree.map(lambda p: np.zeros(p.shape, np.float32),
                              params)
        for p in range(n_pods):
            self.exchange.residuals[p] = resid0
        self.pod_step = [0] * n_pods      # applied steps each pod has seen
        self.applied = 0                  # quorum-committed update count
        self.round_no = 0                 # wall-clock rounds
        self._heal_at: dict[int, set[int]] = {}
        self._counters = dict(partitions=0, heals=0, catchups=0,
                              checkpoints=0, disk_full_events=0)

    # -- checkpoint / catch-up ------------------------------------------------
    def _commit(self) -> bool:
        """The quorum lead commits params + opt + its residual (the whole
        synchronized state a rejoining pod needs).  The lead is the member
        with the most applied steps — a pod that just rejoined stale must
        never author the commit its peers catch up from."""
        quorum = self.exchange.current_quorum()
        if quorum is None:
            return False
        lead = max(quorum, key=lambda p: (self.pod_step[p], -p))
        step = self.pod_step[lead]
        with self.tracer.span("crosspod.commit", step=step, lead=lead):
            self.store.save(step, {
                "params": self.params[lead], "opt": self.opt[lead],
                "residual": self.exchange.residuals[lead],
            }, extra={"applied": step}, sync=True)
        self._counters["checkpoints"] += 1
        return True

    def _heal(self, stale: list[int]) -> None:
        with self.tracer.span("crosspod.heal", pods=stale,
                              round=self.round_no) as sp:
            self.exchange.restore_pods(stale)
            self._counters["heals"] += 1
            behind = [p for p in stale if self.pod_step[p] < self.applied]
            sp.set(behind=behind)
            if not behind or self.exchange.current_quorum() is None:
                return
            # quorum syncs a checkpoint of its *current* state, then each
            # stale pod restores it via the fallback-capable CheckpointStore
            # path
            self._commit()
            for p in behind:
                like = {"params": self.params[p], "opt": self.opt[p],
                        "residual": self.exchange.residuals[p]}
                tree, _, extra = self.store.restore(like)
                self.params[p], self.opt[p] = tree["params"], tree["opt"]
                # stale residual reset + quorum residual adopted: no
                # compression-bias carryover across the partition
                self.exchange.reset_residual(p)
                self.exchange.set_residual(p, tree["residual"])
                self.pod_step[p] = int(extra["applied"])
                self._counters["catchups"] += 1
                self.tracer.event("crosspod.catchup", pod=p,
                                  to_step=self.pod_step[p])
            self.tracer.recovery("net_partition", pods=stale,
                                 caught_up=len(behind))

    # -- chaos ----------------------------------------------------------------
    def _apply_chaos(self, rnd: int) -> None:
        for ev in self.chaos.events_at(rnd):
            if ev.kind == NET_PARTITION:
                minority = self.exchange.partition(ev.targets or (0,))
                self._counters["partitions"] += 1
                heal = rnd + max(1, ev.duration)
                self._heal_at.setdefault(heal, set()).update(minority)
                self.tracer.event("crosspod.partition", round=rnd,
                                  minority=list(minority), heal_round=heal)
            elif ev.kind == DISK_FULL:
                self.store.inject_disk_full()
                self._counters["disk_full_events"] += 1
                # strike now: force a commit through the armed store (the
                # ENOSPC prune-and-retry path runs under the quorum's feet)
                retries_before = self.store.enospc_retries
                self._commit()
                self.tracer.recovery(
                    "disk_full", round=rnd,
                    retries=self.store.enospc_retries - retries_before)
            # every other kind is owned by the coordinator / serve layers

    # -- main loop ------------------------------------------------------------
    def run(self, n_steps: int, *, max_rounds: int | None = None
            ) -> ClusterReport:
        max_rounds = max_rounds or 4 * n_steps + 64
        losses: list[float] = []
        self._commit()   # round-0 partitions must have a commit to land on
        while self.applied < n_steps and self.round_no < max_rounds:
            rnd = self.round_no
            if rnd in self._heal_at:
                self._heal(sorted(self._heal_at.pop(rnd)))
            if self.chaos is not None:
                self._apply_chaos(rnd)
            quorum = self.exchange.current_quorum()
            grads: list = [None] * self.n_pods
            loss = None
            if quorum is not None:
                batch = self.pipeline.batch_at(self.applied)
                for p in quorum:
                    (loss_p, _), g = self._grad(self.params[p], batch)
                    grads[p] = g
                    if loss is None:
                        loss = float(loss_p)
            # sampled split-brain detection: tree_digest forces a device->
            # host sync per pod, so steady-state runs take it every N
            # applied steps (N=1 under --chaos-assert = the exact check)
            take_fp = self.applied % self.fingerprint_every == 0
            res = self.exchange.round(grads, with_fingerprint=take_fp)
            self.round_no += 1
            if res.avg is None:
                self.tracer.event("crosspod.park", round=rnd)
                continue   # whole-cluster park: wall clock lost, no batch
            for p in res.quorum:
                self.params[p], self.opt[p], _ = self._apply(
                    self.params[p], res.avg, self.opt[p])
                self.pod_step[p] = self.applied + 1
            losses.append(loss)
            if take_fp:
                self._fp.inc(status="taken")
                self.exchange.check_round_fingerprints(
                    self.applied, {p: tree_digest(self.params[p])
                                   for p in res.quorum})
            else:
                self._fp.inc(status="skipped")
            self.applied += 1
            if self.applied % self.ckpt_every == 0:
                self._commit()
        # drain pending heals: the run returns a fully-connected cluster
        # (a partition still open at the target step heals now and its
        # stale pods catch up before the final report)
        while self._heal_at:
            rnd = min(self._heal_at)
            self._heal(sorted(self._heal_at.pop(rnd)))
        return ClusterReport(
            steps_completed=self.applied, rounds=self.round_no,
            partitions=self._counters["partitions"],
            parked_pod_rounds=self.exchange.parked_pod_rounds,
            heals=self._counters["heals"],
            catchups=self._counters["catchups"],
            checkpoints=self._counters["checkpoints"],
            split_brain_divergences=self.exchange.split_brain_divergences,
            disk_full_events=self._counters["disk_full_events"],
            enospc_retries=self.store.enospc_retries,
            index_violations=len(self.store.verify_committed()),
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses,
            fingerprints_taken=int(self._fp.value(status="taken")),
            fingerprints_skipped=int(self._fp.value(status="skipped")))
