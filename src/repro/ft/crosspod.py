"""Compressed cross-pod gradient exchange (DCN-aware, host level).

Inside a pod, gradients reduce over ICI in bf16 (the jit'd step).  *Across*
pods the DCN link is ~20x slower, so the pod-level reduction sends int8
gradients with per-tensor scales and error feedback (repro.optim.
grad_compression): 4x fewer DCN bytes than fp32 with a bias that vanishes
over steps.  This module is the host-side transport simulation used by the
tests and the fault_tolerant_train example; on real hardware the exchange
maps 1:1 onto a DCN allgather of the int8 payloads.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.optim.grad_compression import (compress_tree_with_feedback,
                                          decompress_tree)

__all__ = ["PodGradientExchange"]


class PodGradientExchange:
    def __init__(self, n_pods: int):
        self.n_pods = n_pods
        self.residuals = [None] * n_pods   # error-feedback state per pod
        self.bytes_sent_fp32 = 0
        self.bytes_sent_int8 = 0

    def _init_residuals(self, pod: int, grads):
        if self.residuals[pod] is None:
            self.residuals[pod] = jax.tree.map(
                lambda g: np.zeros(g.shape, np.float32), grads)

    def exchange(self, pod_grads: list):
        """pod_grads[p] = gradient pytree from pod p.  Returns the averaged
        (decompressed) gradient tree every pod ends up with."""
        assert len(pod_grads) == self.n_pods
        payloads = []
        for p, g in enumerate(pod_grads):
            self._init_residuals(p, g)
            q, s, r = compress_tree_with_feedback(g, self.residuals[p])
            self.residuals[p] = r
            payloads.append((q, s))
            for leaf in jax.tree.leaves(q):
                self.bytes_sent_int8 += leaf.size        # int8: 1 B each
                self.bytes_sent_fp32 += leaf.size * 4
        # DCN allgather: every pod decompresses every payload and averages
        trees = [decompress_tree(q, s) for q, s in payloads]
        avg = jax.tree.map(lambda *xs: sum(xs) / self.n_pods, *trees)
        return avg

    @property
    def compression_ratio(self) -> float:
        return self.bytes_sent_fp32 / max(self.bytes_sent_int8, 1)
