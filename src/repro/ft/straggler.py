"""Straggler mitigation via the paper's replication heuristics.

The CRCH clustering module (features -> PCA -> triplet agglomeration ->
size-ranked replication counts) is applied to *host telemetry* instead of
workflow tasks: healthy hosts form the big supercluster (1 copy of their
data shard); outlier hosts -- slow, flaky, or hot -- land in small clusters
and their shards get standby replicas on healthy hosts.  Because the data
pipeline is deterministic (repro.data), a replica shard is recomputable
anywhere and "first finish wins" needs no result reconciliation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.clustering import replication_counts, triplet_agglomerate
from repro.core.pca import fit_pca

__all__ = ["HostTelemetry", "ReplicationPlanner"]

TELEMETRY_FEATURES = (
    "mean_step_s", "p95_step_s", "failure_count", "restarts",
    "net_mbps", "mem_pressure", "ecc_errors", "thermal_throttle_s",
)


@dataclasses.dataclass
class HostTelemetry:
    host: int
    mean_step_s: float
    p95_step_s: float
    failure_count: float = 0.0
    restarts: float = 0.0
    net_mbps: float = 0.0
    mem_pressure: float = 0.0
    ecc_errors: float = 0.0
    thermal_throttle_s: float = 0.0

    def vector(self) -> np.ndarray:
        return np.array([getattr(self, f) for f in TELEMETRY_FEATURES])


@dataclasses.dataclass
class ReplicationPlan:
    counts: np.ndarray                  # copies per host's shard
    assignments: dict[int, list[int]]   # shard -> executing hosts
    healthy_hosts: list[int]


class ReplicationPlanner:
    """Unsupervised replication-count learning over host telemetry."""

    def __init__(self, *, cov_threshold: float = 0.35, max_rep: int = 3,
                 R: int = 3, lam: float = 0.5):
        self.cov_threshold = cov_threshold
        self.max_rep = max_rep
        self.R = R
        self.lam = lam

    def plan(self, telemetry: list[HostTelemetry]) -> ReplicationPlan:
        feats = np.stack([t.vector() for t in telemetry])
        n = feats.shape[0]
        pca = fit_pca(feats, self.cov_threshold)
        clustering = triplet_agglomerate(
            pca.projected, n_clusters=min(self.max_rep, n),
            R=self.R, lam=self.lam)
        counts = replication_counts(clustering)
        # hosts in the dominant cluster are the healthy replica targets
        order = np.argsort(-np.asarray(clustering.cluster_sizes))
        healthy = [t.host for t, c in zip(telemetry, clustering.labels)
                   if c == order[0]]
        assignments: dict[int, list[int]] = {}
        rr = 0
        for i, t in enumerate(telemetry):
            hosts = [t.host]
            for _ in range(int(counts[i]) - 1):
                if not healthy:
                    break
                cand = healthy[rr % len(healthy)]
                rr += 1
                if cand not in hosts:
                    hosts.append(cand)
            assignments[i] = hosts
        return ReplicationPlan(counts=counts, assignments=assignments,
                               healthy_hosts=healthy)
