from .checkpoint import CheckpointStore
from .interval import DynamicInterval
from .straggler import ReplicationPlanner, HostTelemetry
from .coordinator import TrainingCoordinator, FaultInjector
from .crosspod import PodGradientExchange

__all__ = ["CheckpointStore", "DynamicInterval", "ReplicationPlanner",
           "HostTelemetry", "TrainingCoordinator", "FaultInjector",
           "PodGradientExchange"]
