from .checkpoint import CheckpointStore
from .interval import DynamicInterval
from .straggler import ReplicationPlanner, HostTelemetry
from .coordinator import TrainingCoordinator, FaultInjector
from .crosspod import (ClusterReport, ExchangeResult, PodGradientExchange,
                       PodTrainingCluster, tree_digest)

__all__ = ["CheckpointStore", "DynamicInterval", "ReplicationPlanner",
           "HostTelemetry", "TrainingCoordinator", "FaultInjector",
           "PodGradientExchange", "PodTrainingCluster", "ExchangeResult",
           "ClusterReport", "tree_digest"]
