"""Replica pool + CRCH-learned per-class hedging budgets (Algorithm 1 online).

``crch_policy`` runs the paper's unsupervised pipeline — request features ->
PCA with coverage-of-variance stop -> triplet agglomerative clustering ->
size-ranked replication counts — over a sample of requests (historical or
the admitted workload) and reduces the per-request counts to a per-
:class:`~repro.serve.queue.RequestClass` budget.  The largest cluster
("ordinary" short requests) gets one copy; outlier clusters (long-decode,
high-exposure requests that are the most likely to be struck by a failure
mid-generation) get hedged with additional replicas on distinct workers.

``WorkerPool`` models the accelerator replicas behind the engine: each
worker owns a contiguous span of decode slots and fails/repairs according to
a :class:`repro.ft.coordinator.FaultInjector` (Weibull MTBF / log-normal
MTTR, the paper's Section 4.1 distributions, in decode-step units).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.clustering import replication_counts, triplet_agglomerate
from repro.core.pca import fit_pca
from repro.ft.coordinator import FaultInjector

from .queue import Request, RequestClass, request_class, request_features

__all__ = [
    "ReplicaPolicy",
    "uniform_policy",
    "crch_policy",
    "SERVE_ENVIRONMENTS",
    "WorkerPool",
]


@dataclasses.dataclass
class ReplicaPolicy:
    """Maps a request to its replication count (total copies to run)."""

    name: str
    by_class: dict[RequestClass, int]
    default: int = 1
    max_rep: int = 4

    def rep_for(self, req: Request) -> int:
        r = self.by_class.get(request_class(req), self.default)
        return int(np.clip(r, 1, self.max_rep))


def uniform_policy(r: int, name: str | None = None) -> ReplicaPolicy:
    """``r=1``: no replication; ``r=k``: Replicate-All(k)."""
    name = name or ("none" if r == 1 else f"all-{r}")
    return ReplicaPolicy(name=name, by_class={}, default=r,
                         max_rep=max(r, 1))


def crch_policy(sample: list[Request], *, cov_threshold: float = 0.35,
                max_rep: int = 3, R: int = 3, lam: float = 0.5,
                backend: str = "jnp") -> ReplicaPolicy:
    """Learn per-class replication from a request sample, unsupervised.

    Identical machinery to ``repro.core.crch.plan`` steps 1-4, with request
    features in place of DAG-task features.  The per-request counts are
    reduced per class with ``max`` — the hedging budget must cover the
    class's worst member.
    """
    if not sample:
        return uniform_policy(1, name="crch")
    feats = request_features(sample)
    pca = fit_pca(feats, cov_threshold)
    clustering = triplet_agglomerate(
        pca.projected, n_clusters=max_rep, R=R, lam=lam, backend=backend)
    counts = replication_counts(
        clustering, priorities=feats[:, 3], exec_times=feats[:, 2])
    by_class: dict[RequestClass, int] = {}
    for req, c in zip(sample, counts):
        cls = request_class(req)
        by_class[cls] = max(by_class.get(cls, 1), int(c))
    return ReplicaPolicy(name="crch", by_class=by_class, default=1,
                         max_rep=max_rep)


# Failure environments in decode-step units, mirroring the shape of
# repro.core.failures.ENVIRONMENTS (stable/normal/unstable = rare /
# occasional / frequent failures, repairs slower as stability drops).
SERVE_ENVIRONMENTS: dict[str, dict] = {
    "stable": {"mtbf_steps": 800.0, "mttr_steps": 8, "shape": 12.5},
    "normal": {"mtbf_steps": 200.0, "mttr_steps": 16, "shape": 12.0},
    "unstable": {"mtbf_steps": 60.0, "mttr_steps": 24, "shape": 11.5},
}


@dataclasses.dataclass
class _Worker:
    wid: int
    down_until: int = 0         # engine step at which the worker is back up
    slow_until: int = 0         # straggling until this step (state intact)

    def is_up(self, step: int) -> bool:
        return step >= self.down_until

    def is_slow(self, step: int) -> bool:
        return step < self.slow_until


class WorkerPool:
    """``n_workers`` simulated accelerator replicas x ``slots_per_worker``
    decode slots each.  Failures take the whole worker down (all its slots
    die simultaneously) for ``mttr_steps``."""

    def __init__(self, n_workers: int, slots_per_worker: int, *,
                 environment: str | None = None, mtbf_steps: float = 0.0,
                 mttr_steps: int = 8, shape: float = 12.0, seed: int = 0,
                 horizon_steps: int = 100_000):
        if environment is not None:
            env = SERVE_ENVIRONMENTS[environment]
            mtbf_steps = env["mtbf_steps"]
            mttr_steps = env["mttr_steps"]
            shape = env["shape"]
        self.n_workers = n_workers
        self.slots_per_worker = slots_per_worker
        self.mttr_steps = int(mttr_steps)
        self.workers = [_Worker(w) for w in range(n_workers)]
        self.injectors: list[FaultInjector | None] = []
        for w in range(n_workers):
            if mtbf_steps and mtbf_steps > 0:
                self.injectors.append(FaultInjector(
                    mtbf_steps=mtbf_steps, shape=shape,
                    mttr_steps=mttr_steps, seed=seed * 1009 + w,
                    horizon_steps=horizon_steps))
            else:
                self.injectors.append(None)
        self.forced_failures: dict[int, list[int]] = {}
        # step -> [(wid, outage duration)] for chaos capacity-loss events
        self.forced_outages: dict[int, list[tuple[int, int]]] = {}

    @property
    def n_slots(self) -> int:
        return self.n_workers * self.slots_per_worker

    def worker_of(self, slot: int) -> int:
        return slot // self.slots_per_worker

    def slots_of(self, wid: int) -> range:
        return range(wid * self.slots_per_worker,
                     (wid + 1) * self.slots_per_worker)

    def is_up(self, wid: int, step: int) -> bool:
        return self.workers[wid].is_up(step)

    def is_slow(self, wid: int, step: int) -> bool:
        return self.workers[wid].is_slow(step)

    def n_up(self, step: int) -> int:
        return sum(w.is_up(step) for w in self.workers)

    def force_failure(self, step: int, wid: int) -> None:
        """Deterministically kill ``wid`` at ``step`` (tests/demos)."""
        self.forced_failures.setdefault(step, []).append(wid)

    def force_outage(self, step: int, wids, duration: int) -> None:
        """Capacity loss: take ``wids`` down at ``step`` for ``duration``
        steps (a chaos ``capacity_loss`` MTTR window)."""
        self.forced_outages.setdefault(step, []).extend(
            (int(w), int(duration)) for w in wids)

    def slow(self, wid: int, until_step: int) -> None:
        """Straggler: ``wid`` stalls (no decode progress, no state loss)
        until ``until_step``."""
        w = self.workers[wid]
        w.slow_until = max(w.slow_until, int(until_step))

    def step_failures(self, step: int) -> list[int]:
        """Workers that fail at ``step``; marks them down for MTTR steps.

        A sampled failure landing while its worker is already down (mid-MTTR)
        is *deferred* to the repair step via :meth:`FaultInjector.defer`
        rather than silently dropped — the fault strikes again the moment the
        worker comes back up.
        """
        failed = []
        outages = dict(self.forced_outages.get(step, ()))
        for w in self.workers:
            inj = self.injectors[w.wid]
            hit = w.wid in self.forced_failures.get(step, ())
            dur = self.mttr_steps
            if w.wid in outages:   # capacity loss carries its own window
                hit = True
                dur = max(dur, outages[w.wid])
            if inj is not None:
                if w.is_up(step):
                    hit = inj.consume(step) or hit
                else:
                    inj.defer(step, w.down_until)
            if hit and w.is_up(step):
                w.down_until = step + dur
                failed.append(w.wid)
        return failed
