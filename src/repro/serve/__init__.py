"""repro.serve — fault-tolerant continuous-batching inference service.

This package carries the paper's offline CRCH machinery (replication
heuristics + synchronized checkpointing, ``repro.core``) into an *online*
serving runtime layered on the jax model stack.  An inference request plays
the role of a DAG task; a decode slot plays the role of a VM; a generated
token plays the role of an execution second.

Architecture / paper mapping
----------------------------

``queue.py`` — admission queue
    Requests carry prompts, decode budgets, deadlines, and priorities, and
    are bucketed into (prompt-length, new-token) *request classes*.  The
    10-dimensional request feature embedding mirrors the task features of
    paper Section 3.1 (work sizes, priority, slack, criticality proxies).

``replicas.py`` — Algorithm 1 online
    ``crch_policy`` applies the exact unsupervised pipeline of Algorithm 1
    to request features instead of DAG tasks: ``request_features`` ->
    ``fit_pca`` (coverage-of-variance stop, steps 2-10) ->
    ``triplet_agglomerate`` (Eq. 5/6 merges, steps 11-16) ->
    ``replication_counts`` (size-ranked rep counts, steps 17-19), reduced to
    a per-class hedged-resubmission budget.  The largest cluster (common
    short requests) runs a single copy; outlier clusters (long-decode,
    failure-exposed requests) are hedged with replicas on distinct workers.
    ``uniform_policy`` provides the Replicate-All and no-replication
    baselines of the paper's comparison.  ``WorkerPool`` models the
    accelerator replicas with Weibull-MTBF / log-normal-MTTR failures
    (Section 4.1) via ``repro.ft.coordinator.FaultInjector``.

``snapshot.py`` — Eq. 10 online
    Synchronized decode-state checkpoints: every ``lambda`` generated
    tokens, one slot's KV-cache row + position + emitted tokens is copied to
    host memory at cost ``gamma``.  Cache-layout agnostic via batch-axis
    probing, so the same code handles dense, RWKV and hybrid cache pytrees.

``engine.py`` — Algorithm 3 online
    The slot-based continuous-batching engine.  Freed slots prefill new
    requests (bucket-padded, per-row ``last_idx`` logits) while live slots
    keep decoding through one jit'd ``make_serve_step`` with a per-slot
    position vector.  Worker failures kill their slots (Case 1); a request
    is resubmitted only when its last copy dies (steps 14-15/25-26),
    resuming from its latest snapshot when one exists (steps 22-23) instead
    of re-prefilling (steps 16-21).  The snapshot cadence is re-derived
    online from observed failures by ``repro.ft.interval.DynamicInterval``
    (Lemma 3.1).  Every model family runs through the engine: recurrent
    (RWKV) and rolling-window hybrid (RG-LRU) caches prefill per request at
    the exact prompt length (their state is not padding-safe), and enc-dec /
    multimodal requests carry per-request side inputs whose derived state
    lives in the slot's cache row.

``reference.py`` — parity oracle
    Batch=1 exact-length static greedy decoding through the same model
    code; token-for-token agreement with the engine certifies that slot
    reuse, padding, masking and snapshot restore are output-transparent.

``metrics.py`` — Section 4.2 online
    Usage (tokens processed across all copies incl. checkpoint overhead),
    wastage (usage minus one clean copy per delivered response, Fig. 8/9),
    goodput (in-deadline completions per 1k steps) and p50/p99 latency.

``benchmarks/serve_slo.py`` reports the no-replication vs. Replicate-All
vs. CRCH comparison under the stable/normal/unstable failure environments —
the serving analogue of the paper's Figs. 8-12 wastage-vs-completion
trade-off.
"""
from .engine import EngineConfig, ServeEngine, engine_supported
from .metrics import ServeMetrics, format_table
from .queue import (AdmissionQueue, Request, RequestClass, WorkItem,
                    prompt_bucket, request_class, request_features)
from .reference import greedy_reference
from .replicas import (SERVE_ENVIRONMENTS, ReplicaPolicy, WorkerPool,
                       crch_policy, uniform_policy)
from .snapshot import DecodeSnapshot, SnapshotStore

__all__ = [
    "AdmissionQueue",
    "DecodeSnapshot",
    "EngineConfig",
    "Request",
    "RequestClass",
    "ReplicaPolicy",
    "SERVE_ENVIRONMENTS",
    "ServeEngine",
    "ServeMetrics",
    "SnapshotStore",
    "WorkItem",
    "WorkerPool",
    "crch_policy",
    "engine_supported",
    "format_table",
    "greedy_reference",
    "prompt_bucket",
    "request_class",
    "request_features",
    "uniform_policy",
]
