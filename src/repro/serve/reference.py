"""Static one-shot greedy reference decoder for token-parity checks.

Decodes each request independently — batch=1, exact-length prefill (no
bucket padding), scalar-position decode loop — through the same
``lm.prefill`` / ``lm.decode_step`` model code the engine jits, but via a
*different* batching path: no slot reuse, no padding, no per-slot position
vectors, no idle-row masking.  Token-for-token agreement between
:func:`greedy_reference` and :class:`~repro.serve.engine.ServeEngine` is
therefore evidence that the engine's continuous-batching machinery (bucket
padding + ``last_idx``, freed-slot reuse, masked cache commits, snapshot
restore) is output-transparent for every model family.

Exactness argument: masked attention scores are set to ``-1e30``, which
underflows to exactly ``0.0`` after the softmax ``exp`` — padded keys
contribute nothing, bit-for-bit, so bucketed and exact-length prefill agree
on every admitted position (and the recurrent families never see padding in
either path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.steps import make_prefill_step, make_serve_step
from repro.models.config import ModelConfig

__all__ = ["greedy_reference"]


def _prefill_batch(cfg: ModelConfig, req) -> dict:
    batch = {"tokens": jnp.asarray(
        np.asarray(req.prompt, np.int32))[None]}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            np.asarray(req.frames, np.float32))[None]
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            np.asarray(req.image_embeds, np.float32))[None]
    return batch


def greedy_reference(params, cfg: ModelConfig, requests, cache_len: int, *,
                     q_chunk: int = 64) -> dict[int, list[int]]:
    """Greedy tokens for each request, rid -> tokens, batch=1 static decode.

    ``cache_len`` should match the engine's so both paths attend over the
    same cache geometry (same rolling-window size for RG-LRU hybrids).
    """
    serve = jax.jit(make_serve_step(cfg))
    prefills: dict[int, object] = {}
    out: dict[int, list[int]] = {}
    offset = cfg.n_image_tokens or 0
    for req in requests:
        p = req.prompt_len
        fn = prefills.get(p)
        if fn is None:
            fn = jax.jit(make_prefill_step(cfg, cache_len,
                                           q_chunk=min(q_chunk, p)))
            prefills[p] = fn
        logits, cache = fn(params, _prefill_batch(cfg, req))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        tokens = [int(np.asarray(tok)[0, 0])]
        for i in range(req.max_new_tokens - 1):
            tok, _, cache = serve(params, cache, tok,
                                  jnp.int32(offset + p + i))
            tokens.append(int(np.asarray(tok)[0, 0]))
        out[req.rid] = tokens
    return out
