"""Serving metrics: goodput, latency percentiles, usage/wastage counters.

Online counterparts of the simulator metrics in ``repro.core.metrics``
(paper Section 4.2):

* **usage** — total tokens *processed* across all request copies: prefill
  tokens (at padded bucket length), decoded tokens, and snapshot overhead
  (the Eq. 10 ``gamma`` term), mirroring "processor seconds spent executing
  task copies";
* **wastage** — processed tokens that did not contribute to a delivered
  response, computed as ``usage - useful`` where useful is one clean copy
  (true prompt + decode budget) per completed request: late-replica tokens,
  beyond-last-snapshot tokens lost to failures, re-prefills, and bucket
  padding all land here, mirroring Fig. 9 (failed requests waste everything
  they executed);
* **goodput** — requests completed within their deadline per 1k decode
  steps (the serving analogue of workflow success rate x 1/TET).

Since the ``repro.obs`` unification the counters live in a
:class:`~repro.obs.metrics.MetricsRegistry` as three labeled families —
``serve_tokens_total{kind=...}``, ``serve_events_total{kind=...}`` and
``serve_drops_total{reason=...}`` — and :class:`ServeMetrics` is a thin
compatibility shim: the legacy attribute names (``metrics.failures += 1``,
``metrics.rejected_on_arrival``) read and write the corresponding labeled
series via ``__getattr__``/``__setattr__``, so the engine and every
existing test keep working unchanged while exporters see one registry.
Pass a shared registry to pool serving series with the rest of a run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs.metrics import MetricsRegistry

__all__ = ["RequestRecord", "ServeMetrics", "format_table"]


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: int
    deadline: int | None
    prompt_len: int
    max_new: int
    completed_step: int | None = None
    shed_step: int | None = None    # load-shed (degraded mode), never ran
    rejected_step: int | None = None   # rejected on arrival (queue bound)
    retry_after: int | None = None     # hint returned with the rejection

    @property
    def completed(self) -> bool:
        return self.completed_step is not None

    @property
    def in_deadline(self) -> bool:
        return (self.completed and
                (self.deadline is None or self.completed_step <= self.deadline))

    @property
    def latency(self) -> float:
        return (float(self.completed_step - self.arrival)
                if self.completed else float("nan"))


class ServeMetrics:
    # legacy attribute -> (registry metric, labels).  Reads and writes on
    # these names go through the registry series; everything else is a
    # normal instance attribute.
    _SERIES = {
        "prefill_tokens": ("serve_tokens_total", {"kind": "prefill"}),
        "decode_tokens": ("serve_tokens_total", {"kind": "decode"}),
        "snapshot_overhead_tokens": ("serve_tokens_total",
                                     {"kind": "snapshot_overhead"}),
        "failures": ("serve_events_total", {"kind": "worker_failure"}),
        "resubmissions": ("serve_events_total", {"kind": "resubmission"}),
        "restores": ("serve_events_total", {"kind": "snapshot_restore"}),
        "snapshots": ("serve_events_total", {"kind": "snapshot"}),
        "capacity_events": ("serve_events_total",
                            {"kind": "capacity_loss"}),
        "slowdown_events": ("serve_events_total", {"kind": "slowdown"}),
        "snapshots_corrupted": ("serve_events_total",
                                {"kind": "snapshot_corrupt"}),
        "snapshot_restore_failures": ("serve_events_total",
                                      {"kind": "snapshot_verify_fail"}),
        "shed": ("serve_drops_total", {"reason": "shed"}),
        "rejected_on_arrival": ("serve_drops_total",
                                {"reason": "rejected_on_arrival"}),
        "hedge_drops": ("serve_drops_total", {"reason": "hedge"}),
        # tripwire: a request past its first token must never be dropped
        "past_first_token_drops": ("serve_drops_total",
                                   {"reason": "past_first_token"}),
    }

    def __init__(self, registry: MetricsRegistry | None = None) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.records: dict[int, RequestRecord] = {}
        self._counters = {
            "serve_tokens_total": self.registry.counter(
                "serve_tokens_total",
                "tokens processed across all request copies, by kind",
                ("kind",)),
            "serve_events_total": self.registry.counter(
                "serve_events_total",
                "serving-side fault/recovery events by kind", ("kind",)),
            "serve_drops_total": self.registry.counter(
                "serve_drops_total",
                "request/copy drops by reason", ("reason",)),
        }

    def __getattr__(self, name):
        # only reached when normal lookup fails, i.e. for _SERIES names
        series = ServeMetrics._SERIES.get(name)
        if series is None:
            raise AttributeError(name)
        metric, labels = series
        return self.__dict__["_counters"][metric].value(**labels)

    def __setattr__(self, name, value) -> None:
        series = ServeMetrics._SERIES.get(name)
        if series is None:
            object.__setattr__(self, name, value)
            return
        metric, labels = series
        self.__dict__["_counters"][metric].set(value, **labels)

    # -- lifecycle hooks (called by the engine) ------------------------------
    def register(self, req) -> None:
        self.records[req.rid] = RequestRecord(
            rid=req.rid, arrival=req.arrival, deadline=req.deadline,
            prompt_len=req.prompt_len, max_new=req.max_new_tokens)

    def complete(self, rid: int, step: int) -> None:
        self.records[rid].completed_step = step

    def mark_shed(self, rid: int, step: int) -> None:
        rec = self.records.get(rid)
        if rec is not None:
            rec.shed_step = step
        self.shed += 1

    def mark_rejected(self, rid: int, step: int, retry_after: int) -> None:
        rec = self.records.get(rid)
        if rec is not None:
            rec.rejected_step = step
            rec.retry_after = retry_after
        self.rejected_on_arrival += 1

    # -- summaries -----------------------------------------------------------
    @property
    def usage_tokens(self) -> float:
        return (self.prefill_tokens + self.decode_tokens +
                self.snapshot_overhead_tokens)

    @property
    def useful_tokens(self) -> float:
        """One clean copy (true prompt + decode budget) per completion."""
        return float(sum(r.prompt_len + r.max_new
                         for r in self.records.values() if r.completed))

    @property
    def wasted_tokens(self) -> float:
        return max(float(self.usage_tokens) - self.useful_tokens, 0.0)

    def summary(self, horizon_steps: int) -> dict[str, float]:
        recs = list(self.records.values())
        lats = np.asarray([r.latency for r in recs if r.completed], float)
        done = sum(r.completed for r in recs)
        good = sum(r.in_deadline for r in recs)
        useful_new = sum(r.max_new for r in recs if r.completed)
        out = {
            "n_requests": float(len(recs)),
            "completed": float(done),
            "in_deadline": float(good),
            "goodput": 1000.0 * good / max(horizon_steps, 1),
            "useful_tok_per_step": useful_new / max(horizon_steps, 1),
            "p50_latency": float(np.percentile(lats, 50)) if lats.size else float("nan"),
            "p99_latency": float(np.percentile(lats, 99)) if lats.size else float("nan"),
            "usage_tokens": float(self.usage_tokens),
            "wasted_tokens": self.wasted_tokens,
            "wastage_frac": self.wasted_tokens / max(self.usage_tokens, 1e-9),
            "failures": float(self.failures),
            "resubmissions": float(self.resubmissions),
            "restores": float(self.restores),
            "snapshots": float(self.snapshots),
            "shed": float(self.shed),
            "rejected_on_arrival": float(self.rejected_on_arrival),
            "hedge_drops": float(self.hedge_drops),
            "snapshot_restore_failures": float(
                self.snapshot_restore_failures),
            "past_first_drops": float(self.past_first_token_drops),
        }
        return out


def format_table(rows: list[dict], columns: list[tuple[str, str]]) -> str:
    """Plain-text table: ``columns`` = [(key, header), ...]."""
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.2f}" if abs(v) < 1e4 else f"{v:.3g}"
        return str(v)

    cells = [[fmt(r.get(k, "")) for k, _ in columns] for r in rows]
    headers = [h for _, h in columns]
    widths = [max(len(h), *(len(c[i]) for c in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.rjust(w) for h, w in zip(headers, widths))
    sep = "  ".join("-" * w for w in widths)
    body = ["  ".join(c.rjust(w) for c, w in zip(row, widths))
            for row in cells]
    return "\n".join([line, sep] + body)
