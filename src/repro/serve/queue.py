"""Admission queue: requests, request classes, deadlines, features.

Serving analogue of the simulator's task model (``repro.core.workflow`` /
``repro.core.features``): an inference *request* plays the role of a DAG
task.  Requests are bucketed into :class:`RequestClass` cells by
(prompt-length bucket, new-token bucket) — the buckets double as the jit
compilation keys for prefill — and embedded into a 10-dimensional feature
space mirroring paper Section 3.1 so the CRCH pipeline (PCA -> triplet
clustering -> replication counts) can learn per-class hedging budgets
unsupervised (see ``repro.serve.replicas``).
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np

__all__ = [
    "Request",
    "RequestClass",
    "WorkItem",
    "AdmissionQueue",
    "prompt_bucket",
    "request_class",
    "request_features",
    "REQUEST_FEATURE_NAMES",
]


@dataclasses.dataclass
class Request:
    """One inference request: prompt tokens + a decode budget + an SLO.

    ``frames`` / ``image_embeds`` are per-request side inputs for the
    encoder-decoder and multimodal families: the audio-frontend frame
    embeddings (n_frames, d_model) and the vision-frontend patch embeddings
    (n_image_tokens, d_model).  They are consumed at prefill — the derived
    per-slot state (cross-attention K/V, image-token KV rows) lives inside
    the slot's cache row afterwards, so snapshots and freed-slot reuse carry
    it automatically; a from-scratch resubmission re-prefills from the arrays
    kept here.
    """

    rid: int
    prompt: np.ndarray              # (P,) int32 token ids
    max_new_tokens: int
    arrival: int = 0                # engine step at which the request arrived
    deadline: int | None = None     # absolute step for SLO-attainment (goodput)
    priority: float = 1.0
    frames: np.ndarray | None = None        # (n_frames, d_model) enc-dec
    image_embeds: np.ndarray | None = None  # (n_image_tokens, d_model) VLM

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[0])

    @property
    def total_work(self) -> int:
        return self.prompt_len + self.max_new_tokens


def prompt_bucket(n: int, *, min_bucket: int = 8) -> int:
    """Next power-of-two >= n (>= min_bucket): the prefill padding length."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """Admission-queue class = (prompt bucket, new-token bucket)."""

    prompt_bucket: int
    new_bucket: int

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return f"p{self.prompt_bucket}/n{self.new_bucket}"


def request_class(req: Request) -> RequestClass:
    return RequestClass(prompt_bucket(req.prompt_len),
                        new_bucket=prompt_bucket(req.max_new_tokens))


REQUEST_FEATURE_NAMES = (
    "prefill_work",     # prompt tokens (analogue of w_t, Eq. 1)
    "decode_work",      # decode budget: time-at-risk during generation
    "total_work",
    "priority",
    "deadline_slack",   # deadline - arrival - total_work (inf-free)
    "decode_frac",      # decode_work / total_work
    "log2_prompt_bucket",
    "log2_new_bucket",
    "urgency",          # total_work / (slack + total_work)
    "restart_cost",     # re-prefill cost on failure without a snapshot
)


def request_features(requests: list[Request],
                     *, slack_cap: float = 4096.0) -> np.ndarray:
    """(N, 10) float feature matrix, axis order ``REQUEST_FEATURE_NAMES``.

    Serving counterpart of ``repro.core.features.task_features``: the
    features deliberately correlate (work sizes appear in several guises)
    exactly as the paper's ten task features do — the PCA stage is what
    de-correlates them.
    """
    feats = np.zeros((len(requests), len(REQUEST_FEATURE_NAMES)))
    for i, r in enumerate(requests):
        p, m = float(r.prompt_len), float(r.max_new_tokens)
        total = p + m
        slack = (float(r.deadline - r.arrival) - total
                 if r.deadline is not None else slack_cap)
        slack = min(slack, slack_cap)
        feats[i] = (
            p,
            m,
            total,
            float(r.priority),
            slack,
            m / max(total, 1.0),
            math.log2(prompt_bucket(r.prompt_len)),
            math.log2(prompt_bucket(r.max_new_tokens)),
            total / max(slack + total, 1.0),
            p,
        )
    return feats


@dataclasses.dataclass
class WorkItem:
    """One schedulable copy of a request.

    A request with replication count ``r`` fans out into ``r`` work items
    (``copy_id`` 0..r-1) that must land on distinct workers — the paper's
    Algorithm 1 ``repCount`` over-provisioning.  A resubmission (all copies
    failed, Algorithm 3 steps 14-15/25-26) re-enters the queue as a new item
    carrying the request's last decode snapshot, if any.
    """

    req: Request
    copy_id: int = 0
    snapshot: object | None = None      # repro.serve.snapshot.DecodeSnapshot
    is_resubmission: bool = False


class AdmissionQueue:
    """FIFO admission queue with head-of-line resubmissions.

    Fresh requests join at the tail in arrival order; resubmissions of
    failed requests jump to the head (Algorithm 3 resubmits "as soon as
    possible").  ``cancel`` drops the pending copies of a request the moment
    one replica completes, so hedges never consume slots posthumously.

    **Queue-length-priced admission**: with ``max_depth`` set, :meth:`admit`
    rejects a fresh request on arrival once depth has crossed the bound and
    returns a ``retry_after`` hint (steps until the backlog ahead of the
    bound drains at ``drain_rate`` tokens/step), so the queue itself stays
    bounded under sustained capacity loss instead of growing without limit.
    Resubmissions always bypass the bound — they carry work already paid
    for.
    """

    def __init__(self, *, max_depth: int | None = None,
                 drain_rate: float = 1.0) -> None:
        self._items: collections.deque[WorkItem] = collections.deque()
        self.max_depth = max_depth
        self.drain_rate = max(float(drain_rate), 1e-9)

    def __len__(self) -> int:
        return len(self._items)

    def submit(self, item: WorkItem) -> None:
        if item.is_resubmission:
            self._items.appendleft(item)
        else:
            self._items.append(item)

    def retry_after_hint(self) -> int:
        """Steps until enough of the backlog ahead of ``max_depth`` drains
        for one fresh item to fit (a lower bound: one decoded token per
        ``1/drain_rate`` steps retires queued work)."""
        if self.max_depth is None:
            return 0
        excess = len(self._items) - self.max_depth + 1
        if excess <= 0:
            return 0
        ahead = [it for i, it in enumerate(self._items) if i < excess]
        tokens = sum(it.req.max_new_tokens for it in ahead)
        return max(1, math.ceil(tokens / self.drain_rate))

    def admit(self, items: list[WorkItem]) -> int | None:
        """All-or-nothing admission of one request's copies.  Returns
        ``None`` on success, or the ``retry_after`` hint (steps) when the
        depth bound rejects the arrival."""
        fresh = items and not any(it.is_resubmission for it in items)
        if (self.max_depth is not None and fresh
                and len(self._items) >= self.max_depth):
            return self.retry_after_hint()
        for it in items:
            self.submit(it)
        return None

    def pop(self, admissible=None) -> WorkItem | None:
        """Pop the first item for which ``admissible(item)`` holds."""
        if admissible is None:
            return self._items.popleft() if self._items else None
        for i, item in enumerate(self._items):
            if admissible(item):
                del self._items[i]
                return item
        return None

    def cancel(self, rid: int) -> int:
        """Remove all pending items of request ``rid``; returns the count."""
        kept = [it for it in self._items if it.req.rid != rid]
        n = len(self._items) - len(kept)
        self._items = collections.deque(kept)
        return n

    def pending_rids(self) -> set[int]:
        return {it.req.rid for it in self._items}

    def items(self) -> tuple[WorkItem, ...]:
        """Read-only view of the queued items, head first."""
        return tuple(self._items)

    def drop_hedges(self) -> int:
        """Degraded mode: keep at most one queued copy per request.

        Under capacity loss the queue stops paying for replication — extra
        queued copies of a request are dropped (never resubmissions, and
        in-flight copies are untouched).  Returns the number dropped.
        """
        seen: set[int] = set()
        kept: list[WorkItem] = []
        dropped = 0
        for it in self._items:
            rid = it.req.rid
            if rid in seen and not it.is_resubmission:
                dropped += 1
                continue
            seen.add(rid)
            kept.append(it)
        self._items = collections.deque(kept)
        return dropped
