"""Slot-based continuous-batching decode engine with fault tolerance.

The serving counterpart of the CheckpointHEFT runtime (paper Algorithm 3):

* a fixed pool of decode *slots* (n_workers x slots_per_worker) advances one
  token per engine step via a single jit'd ``make_serve_step`` call with a
  per-slot position vector — new requests prefill into freed slots while
  live requests keep decoding (no static-batch barrier);
* each admitted request runs ``repCount`` copies on distinct workers
  (:class:`~repro.serve.replicas.ReplicaPolicy`, Algorithm 1); the first
  copy to emit its full budget wins, siblings are cancelled (their tokens
  are the paper's late-replica wastage);
* a worker failure kills all its slots (Algorithm 3 Case 1); only when the
  *last* copy of a request dies is it resubmitted (steps 14-15/25-26) —
  resuming from its latest decode snapshot when one exists (steps 22-23),
  else re-prefilling from scratch (steps 16-21);
* snapshots are taken every ``lambda`` generated tokens per slot, with
  ``lambda`` re-derived online by :class:`repro.ft.interval.DynamicInterval`
  from observed failures (Lemma 3.1).

Supported model families: **all of them**.  Dense / MoE causal-KV
architectures prefill into right-padded buckets (causality + the
overwrite-before-admit cache argument make padding safe).  Recurrent-state
(RWKV) and rolling-window hybrid (RG-LRU) caches are *not* padding-safe —
pad positions would advance the recurrent state — so those families prefill
per request at the exact prompt length instead of a bucket.  Encoder-decoder
and multimodal requests carry their side inputs (encoder frames, image
embeds) on the :class:`~repro.serve.queue.Request`; the derived per-slot
state (cross-attention K/V, image-token KV rows) lands inside the slot's
cache row, so freed-slot reuse and snapshot/restore carry it automatically.
Idle slots are masked out of the batched cache write every tick (stale
``last_token``/``pos`` must never rewrite a freed row), and completed
request state is evicted FIFO beyond ``retain_completed`` so a long-running
service holds bounded host memory.

Chaos hardening (``repro.chaos`` serving-side recovery paths): a
:class:`~repro.chaos.ChaosEngine` passed as ``chaos=`` injects the wider
fault taxonomy each tick — ``host_crash`` / ``capacity_loss`` take workers
down (the latter for its own MTTR window), ``slowdown`` stalls a worker's
slots without losing state (they are masked out of the batched decode until
the straggler recovers, then resume bit-identically), and
``snapshot_corrupt`` flips bytes in a stored decode snapshot.  Recovery:
snapshots are checksum-verified before a resume — a corrupt one is
quarantined and the request re-prefills from scratch; under capacity loss
the admission queue runs **deadline-aware load shedding** (degraded-mode
serving): queued hedge copies collapse to one, and a queued request that
provably cannot meet its deadline even if admitted this very tick is shed,
lowest request class (priority, then slack) first.  A request with a live
copy past its first token is *never* shed — the ``past_first_token_drops``
metric is the tripwire proving it.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.chaos import faults
from repro.distributed.steps import make_prefill_step, make_serve_step
from repro.ft.interval import DynamicInterval
from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs.trace import NULL_TRACER

from .metrics import ServeMetrics
from .queue import AdmissionQueue, Request, WorkItem, prompt_bucket
from .replicas import ReplicaPolicy, WorkerPool, uniform_policy
from .snapshot import (DecodeSnapshot, SnapshotStore, cache_batch_axes,
                       slot_get, slot_set)

__all__ = ["EngineConfig", "ServeEngine", "engine_supported"]


def engine_supported(cfg: ModelConfig) -> tuple[bool, str]:
    """Whether the continuous-batching engine can drive ``cfg``.

    Every assigned family is supported: recurrent state (RWKV) and
    rolling-window hybrids (RG-LRU) via exact-length per-request prefill,
    encoder-decoder and multimodal via per-request side inputs whose derived
    state lives in the slot's cache row.  Kept as a predicate so a future
    family can still be gated with a reason string.
    """
    if cfg.rwkv and cfg.d_model % 64 != 0:
        return False, "rwkv d_model must be a multiple of the 64 head size"
    return True, ""


@dataclasses.dataclass
class EngineConfig:
    cache_len: int = 128
    q_chunk: int = 64
    snapshots_enabled: bool = True
    snapshot_lambda: float | None = None   # None -> DynamicInterval (Lemma 3.1)
    snapshot_gamma: float = 1.0            # per-snapshot cost, token-steps
    prior_mtbf_steps: float = 200.0
    lam_min: float = 2.0
    lam_max: float = 256.0
    # completed requests retained for ``output()`` before FIFO eviction of
    # their request / completed / snapshot entries (bounds engine host state
    # for a long-running service)
    retain_completed: int = 4096
    # degraded mode: deadline-aware admission-queue load shedding under
    # capacity loss (hedge copies collapse first, then provably-late
    # requests are shed lowest-class-first)
    shed_enabled: bool = True
    # queue-length-priced admission: fresh arrivals are rejected with a
    # retry_after hint once queue depth crosses this bound, so the queue
    # stays bounded under sustained capacity loss (None = unbounded)
    max_queue_depth: int | None = None


@dataclasses.dataclass
class _Slot:
    sid: int
    busy: bool = False
    rid: int = -1
    copy_id: int = 0
    pos: int = 0                 # absolute position of the next decode write
    last_token: int = 0
    max_new: int = 0
    since_snapshot: int = 0
    req: Request | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig | None = None, *,
                 pool: WorkerPool, policy: ReplicaPolicy | None = None,
                 params=None, metrics: ServeMetrics | None = None,
                 chaos=None, seed: int = 0, tracer=None):
        ok, why = engine_supported(cfg)
        if not ok:
            raise ValueError(f"{cfg.name}: {why}")
        self.cfg = cfg
        self.ecfg = ecfg or EngineConfig()
        if cfg.rglru and cfg.window and self.ecfg.cache_len < cfg.window:
            raise ValueError(
                f"{cfg.name}: cache_len {self.ecfg.cache_len} < local-"
                f"attention window {cfg.window}; the rolling KV ring and the "
                f"decode slot index (pos % window) would disagree")
        if cfg.is_encdec and self.ecfg.cache_len > cfg.max_decode_len:
            raise ValueError(
                f"{cfg.name}: cache_len {self.ecfg.cache_len} exceeds the "
                f"learned decoder position table ({cfg.max_decode_len})")
        self.pool = pool
        self.chaos = chaos   # repro.chaos.ChaosEngine | None
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.shed: set[int] = set()   # rids dropped in degraded mode
        self.policy = policy or uniform_policy(1)
        self.params = (params if params is not None
                       else lm.init_params(jax.random.key(seed), cfg))
        self.metrics = metrics or ServeMetrics()
        self.queue = AdmissionQueue(max_depth=self.ecfg.max_queue_depth,
                                    drain_rate=max(pool.n_slots, 1))
        self.rejected: dict[int, int] = {}   # rid -> retry_after hint
        self.store = SnapshotStore()
        self.slots = [_Slot(sid) for sid in range(pool.n_slots)]
        self.active: dict[int, set[int]] = {}      # rid -> live slot ids
        self.completed: dict[int, list[int]] = {}  # rid -> delivered tokens
        self.requests: dict[int, Request] = {}
        self._completed_order: collections.deque[int] = collections.deque()
        self.step_no = 0
        self.interval = DynamicInterval(
            gamma_s=self.ecfg.snapshot_gamma, lam_min=self.ecfg.lam_min,
            lam_max=self.ecfg.lam_max,
            prior_mtbf_s=self.ecfg.prior_mtbf_steps)

        cache_len = self.ecfg.cache_len
        self.cache = lm.init_cache(cfg, pool.n_slots, cache_len)
        self.axes = cache_batch_axes(cfg, cache_len)
        self._serve = jax.jit(make_serve_step(cfg, cache_axes=self.axes),
                              donate_argnums=(1,))
        self._get = jax.jit(
            lambda cache, sid: slot_get(cache, self.axes, sid))
        self._set = jax.jit(
            lambda cache, sid, row: slot_set(cache, self.axes, sid, row),
            donate_argnums=(0,))
        self._insert = jax.jit(
            lambda cache, sid, row1: slot_set(
                cache, self.axes, sid,
                jax.tree.map(lambda l, a: jnp.squeeze(l, a), row1,
                             self.axes)),
            donate_argnums=(0,))
        self._prefill_fns: dict[int, callable] = {}

    # -- submission ----------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Enqueue a request; returns its replication count (0 = rejected on
        arrival by the queue-depth bound, with the retry-after hint recorded
        in ``self.rejected[rid]`` and the ``rejected_on_arrival`` metric)."""
        bucket = prompt_bucket(req.prompt_len)
        offset = self.cfg.n_image_tokens or 0
        if offset + bucket + req.max_new_tokens > self.ecfg.cache_len:
            raise ValueError(
                f"request {req.rid}: image tokens {offset} + bucket {bucket} "
                f"+ max_new {req.max_new_tokens} exceeds cache_len "
                f"{self.ecfg.cache_len}")
        if self.cfg.is_encdec and req.frames is None:
            raise ValueError(
                f"request {req.rid}: {self.cfg.name} needs per-request "
                f"encoder frames")
        if offset and req.image_embeds is None:
            raise ValueError(
                f"request {req.rid}: {self.cfg.name} needs per-request "
                f"image embeds")
        self.metrics.register(req)
        rep = self.policy.rep_for(req)
        retry_after = self.queue.admit(
            [WorkItem(req, copy_id=k) for k in range(rep)])
        if retry_after is not None:
            self.rejected[req.rid] = retry_after
            self.metrics.mark_rejected(req.rid, self.step_no, retry_after)
            self.tracer.event("serve.reject", rid=req.rid,
                              retry_after=retry_after)
            return 0
        self.requests[req.rid] = req
        self.tracer.event("serve.admit", rid=req.rid, rep=rep)
        return rep

    # -- chaos injection (repro.chaos taxonomy) ------------------------------
    def _apply_chaos(self, t: int) -> None:
        for ev in self.chaos.events_at(t):
            if ev.kind == faults.HOST_CRASH:
                for wid in (ev.targets or (0,)):
                    self.pool.force_failure(t, wid % self.pool.n_workers)
            elif ev.kind == faults.CAPACITY_LOSS:
                wids = sorted({w % self.pool.n_workers
                               for w in (ev.targets or (0,))})
                self.pool.force_outage(t, wids, ev.duration)
                self.metrics.capacity_events += 1
            elif ev.kind == faults.SLOWDOWN:
                for wid in (ev.targets or (0,)):
                    self.pool.slow(wid % self.pool.n_workers,
                                   t + ev.duration)
                self.metrics.slowdown_events += 1
            elif ev.kind == faults.SNAPSHOT_CORRUPT:
                self.metrics.snapshots_corrupted += \
                    self.store.corrupt(ev.seed)
            # ckpt_corrupt / nan_poison are training-side faults: no-op here

    # -- failures (Algorithm 3 Case 1) ---------------------------------------
    def _on_worker_failures(self, t: int) -> None:
        for wid in self.pool.step_failures(t):
            self.metrics.failures += 1
            self.tracer.event("serve.worker_failure", worker=wid, step=t)
            self.interval.record_failure(float(t))
            self.interval.record_repair(float(self.pool.mttr_steps))
            for sid in self.pool.slots_of(wid):
                slot = self.slots[sid]
                if slot.busy:
                    self._kill_copy(slot, resubmit_if_last=True)

    def _release(self, slot: _Slot) -> None:
        """Free a slot and scrub its decode registers: a freed slot's stale
        ``rid``/``pos``/``last_token`` must never reach the serve step (its
        cache row is additionally masked out of the batched write)."""
        slot.busy = False
        slot.rid = -1
        slot.copy_id = 0
        slot.pos = 0
        slot.last_token = 0
        slot.max_new = 0
        slot.since_snapshot = 0
        slot.req = None
        slot.tokens = []

    def _kill_copy(self, slot: _Slot, *, resubmit_if_last: bool) -> None:
        rid = slot.rid
        had_tokens = bool(slot.tokens)
        live = self.active.get(rid, set())
        live.discard(slot.sid)
        if not live:
            self.active.pop(rid, None)   # prune: empty sets must not linger
        self._release(slot)
        if rid in self.shed:
            # tripwire: shedding must never have dropped a request that was
            # already past its first token (the guard in _shed forbids it)
            if had_tokens:
                self.metrics.past_first_token_drops += 1
            return
        if not resubmit_if_last or rid in self.completed:
            return
        # resubmit only when every copy has failed AND none is still queued
        if not live and rid not in self.queue.pending_rids():
            snap = (self.store.get(rid)
                    if self.ecfg.snapshots_enabled else None)
            self.queue.submit(WorkItem(self.requests[rid], copy_id=0,
                                       snapshot=snap, is_resubmission=True))
            self.metrics.resubmissions += 1
            self.tracer.recovery("host_crash", rid=rid,
                                 from_snapshot=snap is not None)

    # -- degraded mode: deadline-aware load shedding -------------------------
    def _min_finish_step(self, item: WorkItem, t: int) -> int:
        """Earliest step this item could complete if admitted at ``t``.

        A fresh prefill emits its first token at the admit tick AND the slot
        joins the same tick's batched decode (two tokens by end of step
        ``t``); a snapshot resume re-enters with ``e`` tokens banked and
        decodes at ``t``.  The bound must never overshoot — shedding a
        request that could still have met its deadline is forbidden."""
        emitted = len(item.snapshot.tokens) if item.snapshot is not None else 0
        need = item.req.max_new_tokens
        if emitted >= need:
            return t
        return t + need - max(emitted, 1) - 1

    @staticmethod
    def _shed_rank(req: Request):
        """Shedding order: lowest request class first — priority ascending,
        then tightest deadline slack (the least likely to finish)."""
        slack = (req.deadline - req.arrival - req.total_work
                 if req.deadline is not None else float("inf"))
        return (req.priority, slack)

    def _shed(self, t: int) -> None:
        if not self.ecfg.shed_enabled or not len(self.queue):
            return
        # capacity loss -> stop paying for hedges: collapse queued copies
        up_slots = sum(self.pool.slots_per_worker
                       for w in range(self.pool.n_workers)
                       if self.pool.is_up(w, t))
        busy = sum(s.busy for s in self.slots)
        if (up_slots < self.pool.n_slots
                and len(self.queue) > max(up_slots - busy, 0)):
            self.metrics.hedge_drops += self.queue.drop_hedges()
        # shed requests that provably cannot meet their deadline even if
        # admitted this very tick, lowest request class first
        doomed: dict[int, Request] = {}
        for item in self.queue.items():
            dl = item.req.deadline
            if dl is None or self._min_finish_step(item, t) <= dl:
                continue
            doomed.setdefault(item.req.rid, item.req)
        for rid, req in sorted(doomed.items(),
                               key=lambda kv: self._shed_rank(kv[1])):
            if self.active.get(rid):
                # never shed a request with a live copy — once past its
                # first token it either completes or is resubmitted
                continue
            self.queue.cancel(rid)
            self.shed.add(rid)
            self.metrics.mark_shed(rid, t)
            self.tracer.recovery("capacity_loss", rid=rid, action="shed",
                                 step=t)

    # -- admission into freed slots ------------------------------------------
    def _admit(self, t: int) -> None:
        for slot in self.slots:
            wid = self.pool.worker_of(slot.sid)
            if (slot.busy or not self.pool.is_up(wid, t)
                    or self.pool.is_slow(wid, t)):
                continue

            def admissible(item: WorkItem, _wid=wid) -> bool:
                rid = item.req.rid
                if (rid in self.completed or rid in self.shed
                        or item.req.arrival > t):
                    return False
                others = self.active.get(rid, set())
                return all(self.pool.worker_of(s) != _wid for s in others)

            item = self.queue.pop(admissible)
            if item is not None:
                self._start(slot, item, t)

    def _prefill(self, seq: int):
        """Jitted prefill keyed by prompt length.  Dense/MoE/enc-dec/VLM key
        on the power-of-two bucket; the recurrent families key on the exact
        prompt length (one compile per distinct length — the price of
        padding-unsafe state)."""
        fn = self._prefill_fns.get(seq)
        if fn is None:
            fn = jax.jit(make_prefill_step(
                self.cfg, self.ecfg.cache_len,
                q_chunk=min(self.ecfg.q_chunk, seq), with_last_idx=True))
            self._prefill_fns[seq] = fn
        return fn

    def _prefill_batch(self, req: Request, seq: int) -> dict:
        padded = np.zeros((1, seq), np.int32)
        padded[0, :req.prompt_len] = np.asarray(req.prompt, np.int32)
        batch = {"tokens": jnp.asarray(padded)}
        if self.cfg.is_encdec:
            batch["frames"] = jnp.asarray(
                np.asarray(req.frames, np.float32))[None]
        if self.cfg.n_image_tokens:
            batch["image_embeds"] = jnp.asarray(
                np.asarray(req.image_embeds, np.float32))[None]
        return batch

    def _start(self, slot: _Slot, item: WorkItem, t: int) -> None:
        req = item.req
        slot.busy = True
        slot.rid = req.rid
        slot.copy_id = item.copy_id
        slot.max_new = req.max_new_tokens
        slot.req = req
        slot.since_snapshot = 0
        self.active.setdefault(req.rid, set()).add(slot.sid)
        snap: DecodeSnapshot | None = item.snapshot
        if snap is not None and not self.store.verify(snap):
            # checksum mismatch: quarantine the snapshot and fall back to a
            # full re-prefill — never resume from garbage decode state
            self.metrics.snapshot_restore_failures += 1
            self.store.drop(snap.rid)
            self.tracer.recovery("snapshot_corrupt", rid=req.rid,
                                 action="reprefill")
            snap = None
        if snap is not None:
            row = jax.tree.map(jnp.asarray, snap.cache_row)
            self.cache = self._set(self.cache, slot.sid, row)
            slot.pos = snap.pos
            slot.tokens = list(snap.tokens)
            slot.last_token = snap.last_token
            self.metrics.restores += 1
            self.tracer.event("serve.resume", rid=req.rid, pos=snap.pos,
                              banked=len(snap.tokens))
        else:
            p = req.prompt_len
            offset = self.cfg.n_image_tokens or 0
            # recurrent state treats every position as a state update, so pad
            # positions are not maskable after the fact: prefill at the exact
            # prompt length instead of the padded bucket
            exact = self.cfg.rwkv or self.cfg.rglru
            seq = p if exact else prompt_bucket(p)
            with self.tracer.span("serve.prefill", rid=req.rid, seq=seq,
                                  step=t):
                logits, row1 = self._prefill(seq)(
                    self.params, self._prefill_batch(req, seq),
                    jnp.asarray([offset + p - 1], jnp.int32))
            self.cache = self._insert(self.cache, slot.sid, row1)
            tok = int(np.argmax(np.asarray(logits[0])))
            slot.pos = offset + p
            slot.tokens = [tok]
            slot.last_token = tok
            self.metrics.prefill_tokens += seq + offset
        if len(slot.tokens) >= slot.max_new:
            self._finish(slot, t)

    # -- one batched decode step ---------------------------------------------
    def _decode(self, t: int) -> None:
        # straggler slots stall: masked out of the batched write, no token
        # progress, state intact — they resume bit-identically on recovery
        stalled = {s.sid for s in self.slots if s.busy and
                   self.pool.is_slow(self.pool.worker_of(s.sid), t)}
        busy = [s for s in self.slots
                if s.busy and s.sid not in stalled]
        if not busy:
            return
        toks = np.zeros((len(self.slots), 1), np.int32)
        poss = np.zeros((len(self.slots),), np.int32)
        live = np.zeros((len(self.slots),), bool)
        for s in self.slots:
            toks[s.sid, 0] = s.last_token
            poss[s.sid] = s.pos
            live[s.sid] = s.busy and s.sid not in stalled
        with self.tracer.span("serve.decode", track="serve", step=t,
                              live=len(busy), stalled=len(stalled)):
            nxt, _, self.cache = self._serve(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(poss), jnp.asarray(live))
        nxt = np.asarray(nxt)
        for s in busy:
            tok = int(nxt[s.sid, 0])
            s.tokens.append(tok)
            s.last_token = tok
            s.pos += 1
            s.since_snapshot += 1
            self.metrics.decode_tokens += 1
        for s in busy:
            if s.busy and len(s.tokens) >= s.max_new:
                self._finish(s, t)

    def _finish(self, slot: _Slot, t: int) -> None:
        rid = slot.rid
        self.completed[rid] = list(slot.tokens[:slot.max_new])
        self.metrics.complete(rid, t)
        self.tracer.event("serve.finish", rid=rid, step=t,
                          tokens=slot.max_new)
        self.queue.cancel(rid)
        self.store.drop(rid)
        for sid in sorted(self.active.get(rid, set())):
            # late replicas: their tokens become wastage
            self._release(self.slots[sid])
        self.active.pop(rid, None)
        self._completed_order.append(rid)
        while len(self._completed_order) > self.ecfg.retain_completed:
            old = self._completed_order.popleft()
            self.completed.pop(old, None)
            self.requests.pop(old, None)
            self.store.drop(old)

    # -- snapshot cadence (Lemma 3.1 online) ---------------------------------
    def _snapshot_every(self) -> int:
        if self.ecfg.snapshot_lambda is not None:
            return max(1, int(round(self.ecfg.snapshot_lambda)))
        return max(1, int(round(self.interval.current_lambda())))

    def _take_snapshots(self, t: int) -> None:
        if not self.ecfg.snapshots_enabled:
            return
        cadence = self._snapshot_every()
        for s in self.slots:
            if s.busy and s.since_snapshot >= cadence:
                row = jax.device_get(self._get(self.cache, s.sid))
                self.store.save(DecodeSnapshot(
                    rid=s.rid, pos=s.pos, tokens=list(s.tokens),
                    last_token=s.last_token, cache_row=row, step=t))
                self.metrics.snapshots += 1
                self.metrics.snapshot_overhead_tokens += \
                    self.ecfg.snapshot_gamma
                self.tracer.event("serve.snapshot", rid=s.rid, pos=s.pos,
                                  step=t)
                s.since_snapshot = 0

    # -- main loop -----------------------------------------------------------
    def step(self) -> None:
        t = self.step_no
        if self.chaos is not None:
            self._apply_chaos(t)
        self._on_worker_failures(t)
        self._shed(t)
        self._admit(t)
        self._decode(t)
        self._take_snapshots(t)
        self.step_no = t + 1

    def pending(self) -> bool:
        return bool(self.queue) or any(s.busy for s in self.slots)

    def run(self, max_steps: int = 10_000) -> ServeMetrics:
        while self.pending() and self.step_no < max_steps:
            self.step()
        return self.metrics

    def output(self, rid: int) -> list[int] | None:
        return self.completed.get(rid)
