"""Lightweight synchronized decode-state checkpoints (paper Eq. 10 online).

A *decode snapshot* is the serving analogue of the simulator's synchronized
task checkpoint: every ``lambda`` generated tokens the engine copies one
slot's KV-cache row + decode position + emitted tokens to host memory.  When
the worker holding that slot fails, the request resumes from its last
snapshot on any free slot — paying only the tokens generated since the
snapshot instead of a full re-prefill (the paper's "beyond last checkpoint"
waste).  The cadence comes from :class:`repro.ft.interval.DynamicInterval`
(Lemma 3.1: unstable environments snapshot more often).

The slot get/set helpers are cache-layout agnostic: the per-leaf batch axis
is discovered by probing ``lm.init_cache`` shapes at two batch sizes, so the
same code handles dense (L, B, S, H, D), RWKV (L, B, ...) and hybrid
(n_super, rec, B, ...) cache pytrees.

Robustness (the ``repro.chaos`` ``snapshot_corrupt`` recovery path): every
snapshot carries a content checksum computed at save time;
:meth:`SnapshotStore.verify` re-derives it before a restore, so a torn or
corrupted snapshot is detected instead of silently resuming from garbage
state — the engine then quarantines it and falls back to re-prefill.
:meth:`SnapshotStore.corrupt` is the seeded fault injector for that path.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

__all__ = [
    "cache_batch_axes",
    "slot_get",
    "slot_set",
    "DecodeSnapshot",
    "SnapshotStore",
    "snapshot_digest",
]


def cache_batch_axes(cfg: ModelConfig, cache_len: int):
    """Pytree of ints: the batch axis of every cache leaf.

    Probes ``init_cache`` under ``eval_shape`` at batch sizes 2 and 3 — the
    single axis whose extent changes is the batch axis.  No allocation.
    """
    a2 = jax.eval_shape(lambda: lm.init_cache(cfg, 2, cache_len))
    a3 = jax.eval_shape(lambda: lm.init_cache(cfg, 3, cache_len))

    def axis(l2, l3):
        diffs = [i for i, (x, y) in enumerate(zip(l2.shape, l3.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf {l2.shape}")
        return diffs[0]

    return jax.tree.map(axis, a2, a3)


def slot_get(cache, axes, slot):
    """Extract one batch row (slot) from every cache leaf."""
    return jax.tree.map(
        lambda leaf, a: jax.lax.dynamic_index_in_dim(leaf, slot, axis=a,
                                                     keepdims=False),
        cache, axes)


def slot_set(cache, axes, slot, row):
    """Write a single-slot row pytree back into the batched cache."""
    return jax.tree.map(
        lambda leaf, a, r: jax.lax.dynamic_update_index_in_dim(
            leaf, r.astype(leaf.dtype), slot, axis=a),
        cache, axes, row)


@dataclasses.dataclass
class DecodeSnapshot:
    """Host-side resumable decode state of one request."""

    rid: int
    pos: int                    # absolute position of the next decode write
    tokens: list[int]           # tokens emitted up to the snapshot
    last_token: int
    cache_row: object           # single-slot cache pytree (np arrays)
    step: int                   # engine step at which it was taken
    checksum: str = ""          # content hash set by SnapshotStore.save

    def nbytes(self) -> int:
        return int(sum(np.asarray(l).nbytes
                       for l in jax.tree.leaves(self.cache_row)))


def snapshot_digest(snap: DecodeSnapshot) -> str:
    """Content hash over decode registers + tokens + every cache-row leaf."""
    h = hashlib.sha1()
    h.update(np.asarray([snap.rid, snap.pos, snap.last_token],
                        np.int64).tobytes())
    h.update(np.asarray(snap.tokens, np.int64).tobytes())
    for leaf in jax.tree.leaves(snap.cache_row):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


class SnapshotStore:
    """Latest-snapshot-per-request store (the paper keeps only the newest
    synchronized checkpoint; older ones are superseded)."""

    def __init__(self) -> None:
        self._by_rid: dict[int, DecodeSnapshot] = {}
        self.saved = 0
        self.bytes_written = 0
        self.corrupted = 0

    def save(self, snap: DecodeSnapshot) -> None:
        snap.checksum = snapshot_digest(snap)
        self._by_rid[snap.rid] = snap
        self.saved += 1
        self.bytes_written += snap.nbytes()

    def get(self, rid: int) -> DecodeSnapshot | None:
        return self._by_rid.get(rid)

    def drop(self, rid: int) -> None:
        self._by_rid.pop(rid, None)

    def verify(self, snap: DecodeSnapshot) -> bool:
        """True iff the snapshot's content still matches its checksum
        (snapshots without one — hand-built — are trusted)."""
        return not snap.checksum or snap.checksum == snapshot_digest(snap)

    def corrupt(self, seed: int) -> int:
        """Chaos ``snapshot_corrupt``: flip one byte in one stored snapshot.

        Victim snapshot/leaf/byte are pure functions of ``seed`` so a trace
        replay corrupts the exact same state.  Returns 0 when no snapshot
        (or no non-empty leaf) exists, else 1.
        """
        if not self._by_rid:
            return 0
        rids = sorted(self._by_rid)
        snap = self._by_rid[rids[seed % len(rids)]]
        leaves = [np.asarray(l) for l in jax.tree.leaves(snap.cache_row)]
        treedef = jax.tree.structure(snap.cache_row)
        victims = [i for i, l in enumerate(leaves) if l.size]
        if not victims:
            return 0
        vi = victims[seed % len(victims)]
        # device_get rows can be read-only views: flip on a copy and rebuild
        raw = bytearray(np.ascontiguousarray(leaves[vi]).tobytes())
        raw[seed % len(raw)] ^= 0xFF
        leaves[vi] = np.frombuffer(bytes(raw), dtype=leaves[vi].dtype
                                   ).reshape(leaves[vi].shape)
        snap.cache_row = jax.tree.unflatten(treedef, leaves)
        self.corrupted += 1
        return 1

    def __len__(self) -> int:
        return len(self._by_rid)
