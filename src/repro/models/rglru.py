"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [linear -> GeLU gate] * [linear -> causal depthwise conv(4)
-> RG-LRU] -> linear out.  The RG-LRU recurrence

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is a per-channel *linear* recurrence, so training/prefill uses the TPU-native
log-depth ``jax.lax.associative_scan`` rather than a sequential loop; decode
carries ``h`` explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .config import ModelConfig
from .layers import dense_init, _split

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width
    ks = _split(key, 6)
    return {
        "w_gate_branch": dense_init(ks[0], (d, w)),
        "w_rec_branch": dense_init(ks[1], (d, w)),
        "conv_w": 0.1 * dense_init(ks[2], (cfg.conv_width, w)),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa": dense_init(ks[3], (w, w)),
        "ba": jnp.full((w,), 2.0, jnp.float32),   # bias toward remembering
        "wx": dense_init(ks[4], (w, w)),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": jnp.linspace(0.9, 4.0, w).astype(jnp.float32),  # Lambda
        "w_out": dense_init(ks[5], (w, d)),
    }


def _gates(p, u, dtype):
    r = jax.nn.sigmoid((u @ p["wa"].astype(dtype)).astype(jnp.float32)
                       + p["ba"])
    i = jax.nn.sigmoid((u @ p["wx"].astype(dtype)).astype(jnp.float32)
                       + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (..., W) fp32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def _conv_train(p, u, dtype):
    """Causal depthwise conv over time; u: (B, S, W)."""
    width = p["conv_w"].shape[0]
    pads = [jnp.pad(u, ((0, 0), (width - 1 - i, i), (0, 0)))[:, :u.shape[1]]
            for i in range(width)]
    # conv_w[i] multiplies the input delayed by (width-1-i)
    out = sum(pads[i] * p["conv_w"][i].astype(dtype) for i in range(width))
    return out + p["conv_b"].astype(dtype)


def rglru_block_forward(p, x, cfg: ModelConfig, *, return_state: bool = False):
    """Training / prefill path.  Returns (out, state) where state is the
    decode carry {"h": (B, W) fp32, "conv": (B, conv_width-1, W)}."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(dtype))
    u_raw = x @ p["w_rec_branch"].astype(dtype)
    u_raw = constrain(u_raw, ("batch", "seq", "lru"))
    u = _conv_train(p, u_raw, dtype)
    a, b = _gates(p, u, dtype)                    # (B, S, W) fp32

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = constrain(h.astype(dtype), ("batch", "seq", "lru"))
    out = (gate * h) @ p["w_out"].astype(dtype)
    out = constrain(out, ("batch", "seq", "embed"))
    if not return_state:
        return out, h[:, -1].astype(jnp.float32)
    width = p["conv_w"].shape[0]
    conv_tail = u_raw[:, -(width - 1):]
    pad = (width - 1) - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
    return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}


def rglru_block_decode(p, x, state, cfg: ModelConfig):
    """One-step decode.  x: (B, 1, D); state = {"h": (B, W),
    "conv": (B, conv_width-1, W)} (previous conv inputs, oldest first)."""
    dtype = x.dtype
    b = x.shape[0]
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate_branch"].astype(dtype))
    u_new = x[:, 0] @ p["w_rec_branch"].astype(dtype)            # (B, W)
    width = p["conv_w"].shape[0]
    hist = jnp.concatenate([state["conv"], u_new[:, None]], axis=1)
    u = sum(hist[:, i] * p["conv_w"][i].astype(dtype) for i in range(width))
    u = u + p["conv_b"].astype(dtype)
    a, bterm = _gates(p, u, dtype)                               # (B, W)
    h = a * state["h"] + bterm
    out = (gate * h.astype(dtype)) @ p["w_out"].astype(dtype)
    new_state = {"h": h, "conv": hist[:, 1:]}
    return out[:, None], new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}
