from .config import ModelConfig
from . import layers, lm, rglru, rwkv6

__all__ = ["ModelConfig", "layers", "lm", "rglru", "rwkv6"]
