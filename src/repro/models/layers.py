"""Shared model primitives: norms, rotary, GQA attention, MLP, MoE.

Pure-functional (param pytrees of jnp arrays); compute in bf16 with fp32
softmax/normalization; activation shardings are *logical* annotations via
``repro.distributed.sharding.constrain``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .config import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(jnp.float32)


def _split(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p = {"scale": jnp.ones((d,), jnp.float32)}
        if cfg.use_bias:
            p["bias"] = jnp.zeros((d,), jnp.float32)
        return p
    if cfg.norm_type == "nonparametric_ln":   # OLMo
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ModelConfig, params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (nrm * params["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    nrm = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm_type == "layernorm":
        nrm = nrm * params["scale"]
        if "bias" in params:
            nrm = nrm + params["bias"]
    return nrm.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, full / local-window / cross; train + decode paths)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, d_model: int | None = None,
                   n_heads: int | None = None, n_kv: int | None = None):
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.head_dim
    ks = _split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d), in_axis=0),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, n_heads, n_kv, dtype):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if "bq" in p:
        q, k, v = (q + p["bq"].astype(dtype), k + p["bk"].astype(dtype),
                   v + p["bv"].astype(dtype))
    q = q.reshape(b, s, n_heads, hd)
    k = k.reshape(b, s, n_kv, hd)
    v = v.reshape(b, s, n_kv, hd)
    return q, k, v


def _sdpa(q, k, v, mask, *, q_chunk: int = 1024):
    """Chunked scaled-dot-product attention (GQA) with fp32 softmax.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); mask(q_pos, k_pos) callable
    returning a boolean (Bq, Sk) block, or None.  Scanning over query chunks
    keeps the live score tensor at (B, H, q_chunk, Sk).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kv, g, d)
    # (B, KV, G, Sq, Sk) einsum operands
    kT = k.transpose(0, 2, 3, 1)                      # (B, KV, D, Sk)

    def block(q_blk, q_pos):
        # q_blk: (B, C, KV, G, D).  No sharding constraint on the scores:
        # GQA head counts (56, 96, 10, ...) rarely divide the model axis, and
        # forcing heads->model here made GSPMD insert "involuntary full
        # rematerialization" copies (+70 GiB/device on deepseek train_4k) --
        # propagation from the projections picks a consistent (kv, g) split.
        scores = jnp.einsum("bckgd,bkds->bkgcs", q_blk, kT,
                            preferred_element_type=jnp.float32) * scale
        if mask is not None:
            m = mask(q_pos)                            # (C, Sk) bool
            scores = jnp.where(m[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgcs,bskd->bckgd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype)

    if sq % q_chunk != 0:
        # largest divisor of sq not exceeding q_chunk (llava's 576+text
        # sequences are not power-of-two); tiny divisors -> single block
        q_chunk = max((c for c in range(1, q_chunk + 1) if sq % c == 0),
                      default=sq)
        if q_chunk < 64:
            q_chunk = sq
    if sq <= q_chunk:
        out = block(qg, jnp.arange(sq))
    else:
        n_blk = sq // q_chunk
        qb = qg.reshape(b, n_blk, q_chunk, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
        pos = jnp.arange(sq).reshape(n_blk, q_chunk)

        def body(_, inp):
            qq, pp = inp
            return None, block(qq, pp)

        _, outs = jax.lax.scan(body, None, (qb, pos))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kv, g, d)
    return out.reshape(b, sq, h, d)


def attention_forward(p, x, cfg: ModelConfig, *, positions, mode: str,
                      window: int = 0, n_heads=None, n_kv=None,
                      context=None, q_chunk: int = 1024,
                      return_kv: bool = False):
    """mode: causal | local | bidir | cross (context = encoder output)."""
    dtype = x.dtype
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    if mode == "cross":
        b, s, _ = x.shape
        hd = cfg.head_dim
        q = (x @ p["wq"].astype(dtype)).reshape(b, s, h, hd)
        sk = context.shape[1]
        k = (context @ p["wk"].astype(dtype)).reshape(b, sk, kv, hd)
        v = (context @ p["wv"].astype(dtype)).reshape(b, sk, kv, hd)
        mask = None
    else:
        q, k, v = _project_qkv(p, x, cfg, h, kv, dtype)
        if mode != "bidir":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        sk = k.shape[1]
        kpos = jnp.arange(sk)
        if mode == "causal":
            mask = lambda qp: qp[:, None] >= kpos[None, :]
        elif mode == "local":
            mask = lambda qp: ((qp[:, None] >= kpos[None, :]) &
                               (qp[:, None] - kpos[None, :] < window))
        elif mode == "bidir":
            mask = None
        else:
            raise ValueError(mode)
    q = constrain(q, ("batch", "seq", None, None))
    out = _sdpa(q, k, v, mask, q_chunk=q_chunk)
    out = out.reshape(*out.shape[:2], -1)
    out = out @ p["wo"].astype(dtype)
    if "bo" in p:
        out = out + p["bo"].astype(dtype)
    out = constrain(out, ("batch", "seq", "embed"))
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(p, x, cache, cfg: ModelConfig, *, pos, window: int = 0,
                     n_heads=None, n_kv=None, cross_kv=None):
    """One-token decode. cache = {"k","v"}: (B, S_cache, KV, D); ``pos`` is
    the absolute position, either a scalar int32 shared by the batch or a
    per-row (B,) vector (continuous batching: every slot decodes at its own
    position).  For ``window>0`` the cache is a rolling buffer of length
    ``window``.  ``cross_kv`` short-circuits to cross-attention against
    precomputed encoder K/V."""
    dtype = x.dtype
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.head_dim
    b = x.shape[0]
    if cross_kv is not None:
        q = (x @ p["wq"].astype(dtype)).reshape(b, 1, h, hd)
        k, v = cross_kv
        valid = None
        new_cache = cache
    else:
        q, k_new, v_new = _project_qkv(p, x, cfg, h, kv, dtype)
        per_row = jnp.ndim(pos) > 0
        posb = (jnp.reshape(pos, (b, 1)).astype(jnp.int32) if per_row
                else jnp.full((b, 1), pos, jnp.int32))
        q = rope(q, posb, cfg.rope_theta)
        k_new = rope(k_new, posb, cfg.rope_theta)
        s_cache = cache["k"].shape[1]
        idx = jnp.arange(s_cache)
        if per_row:
            slot = posb[:, 0] % window if window else posb[:, 0]

            def upd(c, u, s):
                return jax.lax.dynamic_update_slice(c, u.astype(c.dtype),
                                                    (s, 0, 0))

            k = jax.vmap(upd)(cache["k"], k_new, slot)
            v = jax.vmap(upd)(cache["v"], v_new, slot)
            if window:
                valid = (idx[None] <= posb % window) | (posb >= window)
                valid = valid & (idx[None] < window)
            else:
                valid = idx[None] <= posb                    # (B, S_cache)
            valid = valid[:, None, None, :]
        else:
            slot = pos % window if window else pos
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
            if window:
                valid = (idx <= pos % window) | (pos >= window)
                valid = valid & (idx < window)
            else:
                valid = idx <= pos
            valid = valid[None, None, None]
        new_cache = {"k": k, "v": v}
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    # flash-decoding split: the cache *sequence* lives on the model axis
    # (GQA head counts rarely divide 16; seq_len always does)
    scores = constrain(scores, ("batch", "kv_heads", None, "kv_seq"))
    if valid is not None:
        scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(dtype), v.astype(dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    out = out.reshape(b, 1, h * hd)
    out = out @ p["wo"].astype(dtype)
    if "bo" in p:
        out = out + p["bo"].astype(dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d: int | None = None,
             ff: int | None = None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    ks = _split(key, 3)
    if cfg.mlp_type == "gelu":
        p = {"w_up": dense_init(ks[1], (d, ff)),
             "w_down": dense_init(ks[2], (ff, d))}
        if cfg.use_bias:
            p["b_up"] = jnp.zeros((ff,), jnp.float32)
            p["b_down"] = jnp.zeros((d,), jnp.float32)
        return p
    return {
        "w_gate": dense_init(ks[0], (d, ff)),
        "w_up": dense_init(ks[1], (d, ff)),
        "w_down": dense_init(ks[2], (ff, d)),
    }


def mlp_forward(p, x):
    dtype = x.dtype
    if "w_gate" not in p:                       # gelu MLP (whisper)
        h = x @ p["w_up"].astype(dtype)
        if "b_up" in p:
            h = h + p["b_up"].astype(dtype)
        h = constrain(jax.nn.gelu(h), ("batch", "seq", "mlp"))
        out = h @ p["w_down"].astype(dtype)
        if "b_down" in p:
            out = out + p["b_down"].astype(dtype)
        return constrain(out, ("batch", "seq", "embed"))
    gate = jax.nn.silu(x @ p["w_gate"].astype(dtype))
    up = x @ p["w_up"].astype(dtype)
    h = constrain(gate * up, ("batch", "seq", "mlp"))
    return constrain(h @ p["w_down"].astype(dtype), ("batch", "seq", "embed"))


def init_moe(key, cfg: ModelConfig):
    ks = _split(key, 4)
    e, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, ff), in_axis=1),
        "w_up": dense_init(ks[2], (e, d, ff), in_axis=1),
        "w_down": dense_init(ks[3], (e, ff, d), in_axis=1),
    }


MOE_GROUP = 2048  # tokens per dispatch group (GShard-style local capacity)


def moe_forward(p, x, cfg: ModelConfig):
    """GShard-style grouped top-k dispatch with capacity.

    Tokens are dispatched within *groups* of <= MOE_GROUP tokens (per
    sequence slice), so the one-hot dispatch/combine tensors are
    (G_count, G, E, C_g) with C_g = ceil(G*k/E*cf) -- never the quadratic
    (N, E, N*k/E) blow-up of a global dispatch.  Returns (out, aux_loss).
    """
    dtype = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if s >= MOE_GROUP and s % MOE_GROUP == 0:
        g_count, g = b * (s // MOE_GROUP), MOE_GROUP
    elif s == 1:
        g_count, g = 1, b       # decode: one group across the batch
    else:
        g_count, g = b, s
    xt = x.reshape(g_count, g, d)
    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (B,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (B,G,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    cap = int(math.ceil(g * k / e * cfg.capacity_factor))
    cap = max(cap, 4)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)        # (B,G,k,E)
    flat = onehot.reshape(g_count, g * k, e)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(g_count, g, k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                      # (B,G,k)
    keep = (pos < cap).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)           # (B,G,k,C)
    disp = jnp.einsum("bgke,bgkc->bgec", onehot, pos_oh * keep[..., None])
    comb = jnp.einsum("bgec,bgk->bgec", disp, gate_vals.astype(jnp.float32))
    xe = jnp.einsum("bgd,bgec->becd", xt.astype(jnp.float32),
                    disp).astype(dtype)                            # (B,E,C,D)
    xe = constrain(xe, (None, "experts", "expert_capacity", "embed"))
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                                  p["w_gate"].astype(dtype)))
    up = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(dtype))
    ye = jnp.einsum("becf,efd->becd", gate * up, p["w_down"].astype(dtype))
    ye = constrain(ye, (None, "experts", "expert_capacity", "embed"))
    out = jnp.einsum("becd,bgec->bgd", ye.astype(jnp.float32),
                     comb).astype(dtype)
    # load-balancing auxiliary loss (Switch)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return constrain(out.reshape(b, s, d), ("batch", "seq", "embed")), aux
