"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token/time mixing.

Time-mix per head (head dim N = 64):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (matrix state, K x V)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with *data-dependent* per-channel decay w_t = exp(-exp(ww + lora(x_t))) and
token-shift ddlerp mixing.  Training/prefill uses the chunked formulation
(chunk = 16) so everything is MXU matmuls with safe fp32 exponents; decode
carries (S, last_x) explicitly.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .config import ModelConfig
from .layers import dense_init, _split

HEAD_N = 64          # RWKV-6 head size
CHUNK = 16           # chunk length: exp arguments stay within fp32 range
LOG_W_MIN = -2.5     # per-token decay clamp (w >= e^-2.5)
LORA_R = 32


def n_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % HEAD_N == 0
    return cfg.d_model // HEAD_N


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = _split(key, 10)
    p = {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),     # r,k,v,w,g ddlerp base
        "lora_a": 0.01 * dense_init(ks[0], (d, LORA_R * 5)),
        "lora_b": 0.01 * dense_init(ks[1], (5, LORA_R, d), in_axis=1),
        "wr": dense_init(ks[2], (d, d)),
        "wk": dense_init(ks[3], (d, d)),
        "wv": dense_init(ks[4], (d, d)),
        "wg": dense_init(ks[5], (d, d)),
        "wo": dense_init(ks[6], (d, d)),
        "ww": jnp.full((d,), -0.6, jnp.float32),       # decay base
        "w_lora_a": 0.01 * dense_init(ks[7], (d, LORA_R)),
        "w_lora_b": 0.01 * dense_init(ks[8], (LORA_R, d)),
        "u": 0.1 * dense_init(ks[9], (d,)),            # bonus
        "ln_scale": jnp.ones((d,), jnp.float32),       # group-norm on heads
    }
    return p


def init_channel_mix(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    ks = _split(key, 3)
    return {
        "mu_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mu_r": 0.5 * jnp.ones((d,), jnp.float32),
        "wk": dense_init(ks[0], (d, ff)),
        "wv": dense_init(ks[1], (ff, d)),
        "wr": dense_init(ks[2], (d, d)),
    }


def _ddlerp(p, x, x_prev, dtype):
    """Data-dependent token-shift mixing -> the 5 mixed inputs (r,k,v,w,g)."""
    xx = x_prev - x                                        # (B, S, D)
    coarse = x + xx * p["mu"][:, None, None, :].astype(dtype)   # (5,B,S,D)
    lora = jnp.tanh((x + 0.5 * xx) @ p["lora_a"].astype(dtype))
    lora = lora.reshape(*x.shape[:-1], 5, LORA_R)
    delta = jnp.einsum("bsfr,frd->fbsd", lora, p["lora_b"].astype(dtype))
    return coarse + xx * delta


def _decay(p, xw, dtype):
    """Per-token per-channel log decay, clamped for chunked stability."""
    lo = jnp.tanh(xw @ p["w_lora_a"].astype(dtype)) @ p["w_lora_b"].astype(dtype)
    log_w = -jnp.exp((p["ww"] + lo.astype(jnp.float32)).clip(-8.0, 1.0))
    return log_w.clip(LOG_W_MIN, -1e-4)                    # (B, S, D) fp32


def _group_norm(p, o, h):
    """Per-head LayerNorm on the flattened (H*N) output."""
    of = o.astype(jnp.float32)
    mean = of.mean(-1, keepdims=True)
    var = of.var(-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 64e-5)
    of = of.reshape(*of.shape[:-2], h * HEAD_N) * p["ln_scale"]
    return of


def time_mix_forward(p, x, x_prev_last, cfg: ModelConfig):
    """Chunked WKV6. x: (B, S, D) with S % CHUNK == 0.
    Returns (out, (S_state, last_x))."""
    dtype = x.dtype
    b, s, d = x.shape
    h = n_heads(cfg)
    x_prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev, dtype)
    r = (xr @ p["wr"].astype(dtype)).reshape(b, s, h, HEAD_N)
    k = (xk @ p["wk"].astype(dtype)).reshape(b, s, h, HEAD_N)
    v = (xv @ p["wv"].astype(dtype)).reshape(b, s, h, HEAD_N)
    g = jax.nn.silu(xg @ p["wg"].astype(dtype))
    log_w = _decay(p, xw, dtype).reshape(b, s, h, HEAD_N)
    u = p["u"].reshape(h, HEAD_N)

    s_main = (s // CHUNK) * CHUNK
    tail = s - s_main

    def chunkify(t, n):
        t = t[:, :s_main] if n else t
        return t.reshape(b, -1, CHUNK, h, HEAD_N).transpose(1, 0, 3, 2, 4)

    nc = s_main // CHUNK
    rc = chunkify(r, tail)
    kc = chunkify(k, tail)
    vc = chunkify(v, tail)
    wc = chunkify(log_w, tail)

    def chunk_step(S, inp):
        rcb, kcb, vcb, wcb = inp          # (B, H, T, N) fp32/dtype
        cum = jnp.cumsum(wcb, axis=2)     # inclusive logP_t
        p_prev = jnp.exp(cum - wcb)       # logP_{t-1} = cum - w_t
        p_inv = jnp.exp(-cum)
        p_end = jnp.exp(cum[:, :, -1:])   # (B,H,1,N)
        rcb32 = rcb.astype(jnp.float32)
        kcb32 = kcb.astype(jnp.float32)
        vcb32 = vcb.astype(jnp.float32)
        # inter-chunk: r_t decayed against entering state
        o_inter = jnp.einsum("bhtn,bhnm->bhtm", rcb32 * p_prev, S)
        # intra-chunk: A[t,j] = (r_t p_{t-1}) . (k_j / p_j)  for j < t
        A = jnp.einsum("bhtn,bhjn->bhtj", rcb32 * p_prev, kcb32 * p_inv,
                       preferred_element_type=jnp.float32)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), jnp.float32), k=-1)
        A = A * tri
        # bonus diagonal term: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bhtn,bhtn->bht", rcb32,
                           u[None, :, None, :] * kcb32)
        o = o_inter + jnp.einsum("bhtj,bhjm->bhtm", A, vcb32) \
            + bonus[..., None] * vcb32
        # state update: S' = diag(p_end) S + sum_j (p_end / p_j) k_j v_j
        kd = kcb32 * (p_end * p_inv)
        S_new = p_end.transpose(0, 1, 3, 2) * S + \
            jnp.einsum("bhjn,bhjm->bhnm", kd, vcb32)
        return S_new, o.astype(dtype)

    S0 = jnp.zeros((b, h, HEAD_N, HEAD_N), jnp.float32)
    if nc > 0:
        # remat the chunk body: without it the scan saves every chunk's
        # (B,H,T,T) A-matrix and decay tensors for backward (~10 GiB/device
        # at train_4k; see EXPERIMENTS.md section Perf)
        S_fin, oc = jax.lax.scan(jax.checkpoint(chunk_step), S0,
                                 (rc, kc, vc, wc))
        o = oc.transpose(1, 0, 3, 2, 4).reshape(b, s_main, h, HEAD_N)
    else:
        S_fin, o = S0, jnp.zeros((b, 0, h, HEAD_N), dtype)
    if tail:
        # sub-chunk remainder: plain per-token recurrence
        def tok_step(S, inp):
            rt, kt, vt, wt = (t.astype(jnp.float32) for t in inp)
            kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
            ot = jnp.einsum("bhn,bhnm->bhm", rt,
                            S + u[None, :, :, None] * kv)
            S = jnp.exp(wt)[..., None] * S + kv
            return S, ot.astype(dtype)

        seqs = tuple(t[:, s_main:].transpose(1, 0, 2, 3)
                     for t in (r, k, v, log_w))
        S_fin, o_tail = jax.lax.scan(tok_step, S_fin, seqs)
        o = jnp.concatenate([o, o_tail.transpose(1, 0, 2, 3)], axis=1)
    o = _group_norm(p, o, h).astype(dtype) * g
    out = o @ p["wo"].astype(dtype)
    return constrain(out, ("batch", "seq", "embed")), (S_fin, x[:, -1])


def time_mix_decode(p, x, state, cfg: ModelConfig):
    """x: (B, 1, D); state = (S (B,H,N,N) fp32, last_x (B, D))."""
    dtype = x.dtype
    S, last_x = state
    b, _, d = x.shape
    h = n_heads(cfg)
    xr, xk, xv, xw, xg = _ddlerp(p, x, last_x[:, None], dtype)
    r = (xr @ p["wr"].astype(dtype)).reshape(b, h, HEAD_N).astype(jnp.float32)
    k = (xk @ p["wk"].astype(dtype)).reshape(b, h, HEAD_N).astype(jnp.float32)
    v = (xv @ p["wv"].astype(dtype)).reshape(b, h, HEAD_N).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(dtype))[:, 0]
    w = jnp.exp(_decay(p, xw, dtype).reshape(b, h, HEAD_N))
    u = p["u"].reshape(h, HEAD_N)
    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    o = jnp.einsum("bhn,bhnm->bhm", r, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    o = _group_norm(p, o, h)                       # (B, H*N)
    out = (o.astype(dtype) * g) @ p["wo"].astype(dtype)
    return out[:, None], (S_new, x[:, 0])


def channel_mix_forward(p, x, x_prev_last, dtype=None):
    dtype = dtype or x.dtype
    x_prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(dtype)
    xr = x + xx * p["mu_r"].astype(dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dtype)))
    kk = constrain(kk, ("batch", "seq", "mlp"))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dtype)) * (kk @ p["wv"].astype(dtype))
    return constrain(out, ("batch", "seq", "embed")), x[:, -1]


def channel_mix_decode(p, x, last_x):
    dtype = x.dtype
    xx = last_x[:, None] - x
    xk = x + xx * p["mu_k"].astype(dtype)
    xr = x + xx * p["mu_r"].astype(dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dtype)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dtype)) * (kk @ p["wv"].astype(dtype))
    return out, x[:, 0]
