"""Model assembly for all assigned architectures.

Pure-functional API:

  init_params(key, cfg)                          -> params pytree
  forward_train(params, cfg, batch)              -> (loss, metrics)
  prefill(params, cfg, batch, cache_len)         -> (last_logits, cache)
  init_cache(cfg, batch_size, cache_len)         -> cache pytree
  decode_step(params, cfg, cache, tokens, pos)   -> (logits, cache)

Layers are *stacked* along a leading L axis and traversed with ``lax.scan``
(+ optional ``jax.checkpoint``), keeping HLO size O(1) in depth -- essential
for the 512-device dry-run compiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import rglru as rg
from . import rwkv6 as rw
from .config import ModelConfig
from .layers import (apply_norm, attention_decode, attention_forward,
                     dense_init, init_attention, init_mlp, init_moe,
                     init_norm, mlp_forward, moe_forward, _split)

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(key, n, init_fn):
    """Initialize n layers and stack each leaf along axis 0."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def chunked_xent(h, w_out, targets, mask, *, chunk: int = 512):
    """Cross-entropy without materializing full (B, S, V) logits.

    The chunk body is rematerialized: without ``jax.checkpoint`` the scan
    saves every chunk's (B, C, V) fp32 logits for the backward pass, which
    costs ~seq/chunk x the live set (measured +50 GiB/device on the olmo /
    whisper train_4k dry-runs; see EXPERIMENTS.md section Perf, iteration 1).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    @jax.checkpoint
    def piece(hc, tc, mc):
        logits = (hc @ w_out).astype(jnp.float32)           # (B, C, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], -1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    if n > 0:
        hcs = h[:, :n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
        tcs = targets[:, :n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
        mcs = mask[:, :n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            tot, cnt = carry
            t, c = piece(*inp)
            return (tot + t, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hcs, tcs, mcs))
    else:
        tot = cnt = 0.0
    if rem:
        t, c = piece(h[:, n * chunk:], targets[:, n * chunk:],
                     mask[:, n * chunk:])
        tot, cnt = tot + t, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg: ModelConfig):
    ks = _split(key, 4)
    p = {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg),
    }
    if cfg.block_type != "parallel":
        p["ln2"] = init_norm(cfg, cfg.d_model)
    p["moe" if cfg.is_moe else "mlp"] = (
        init_moe(ks[1], cfg) if cfg.is_moe else init_mlp(ks[1], cfg))
    return p


def _init_rec_layer(key, cfg: ModelConfig):
    ks = _split(key, 2)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "rec": rg.init_rglru_block(ks[0], cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(ks[1], cfg),
    }


def _init_attn_layer(key, cfg: ModelConfig):
    ks = _split(key, 2)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(ks[1], cfg),
    }


def _init_rwkv_layer(key, cfg: ModelConfig):
    ks = _split(key, 2)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "tm": rw.init_time_mix(ks[0], cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "cm": rw.init_channel_mix(ks[1], cfg),
    }


def _init_cross_layer(key, cfg: ModelConfig):
    ks = _split(key, 3)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg),
        "ln_x": init_norm(cfg, cfg.d_model),
        "xattn": init_attention(ks[1], cfg),
        "ln2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(ks[2], cfg),
    }


def hybrid_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(#super blocks of [rec]*k+[attn], #tail rec layers)."""
    span = cfg.rec_per_attn + 1
    return cfg.n_layers // span, cfg.n_layers % span


def init_params(key, cfg: ModelConfig):
    ks = _split(key, 8)
    d = cfg.d_model
    params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, d), in_axis=1),
        "final_norm": init_norm(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (d, cfg.vocab_size))

    if cfg.rwkv:
        params["ln_in"] = init_norm(cfg, d)
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _init_rwkv_layer(k, cfg))
    elif cfg.rglru:
        n_super, n_tail = hybrid_layout(cfg)
        params["super"] = _stack_init(ks[2], n_super, lambda k: {
            "rec": _stack_init(k, cfg.rec_per_attn,
                               lambda k2: _init_rec_layer(k2, cfg)),
            "attn": _init_attn_layer(jax.random.fold_in(k, 1), cfg),
        })
        if n_tail:
            params["tail"] = _stack_init(
                ks[3], n_tail, lambda k: _init_rec_layer(k, cfg))
    elif cfg.is_encdec:
        params["enc_pos"] = 0.02 * dense_init(ks[4], (cfg.n_frames, d))
        params["dec_pos"] = 0.02 * dense_init(ks[5], (cfg.max_decode_len, d))
        params["enc_layers"] = _stack_init(
            ks[2], cfg.encoder_layers, lambda k: _init_attn_layer(k, cfg))
        params["enc_norm"] = init_norm(cfg, d)
        params["layers"] = _stack_init(
            ks[3], cfg.n_layers, lambda k: _init_cross_layer(k, cfg))
    else:
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _init_dense_layer(k, cfg))
    pdt = jnp.dtype(cfg.param_dtype)
    if pdt != jnp.float32:
        # production dtype: bf16 weights on device; the fp32 master copy
        # lives (sharded) in the optimizer state (ZeRO-1)
        params = jax.tree.map(lambda x: x.astype(pdt), params)
    return params


# ---------------------------------------------------------------------------
# blocks (single-layer forward, used under scan)
# ---------------------------------------------------------------------------

def _dense_block(p, x, cfg: ModelConfig, positions, *, mode="causal",
                 window=0, q_chunk=1024):
    if cfg.block_type == "parallel":                  # Cohere command-r
        h = apply_norm(cfg, p["ln1"], x)
        a = attention_forward(p["attn"], h, cfg, positions=positions,
                              mode=mode, window=window, q_chunk=q_chunk)
        if cfg.is_moe:
            m, aux = moe_forward(p["moe"], h, cfg)
        else:
            m, aux = mlp_forward(p["mlp"], h), 0.0
        return x + a + m, aux
    h = apply_norm(cfg, p["ln1"], x)
    x = x + attention_forward(p["attn"], h, cfg, positions=positions,
                              mode=mode, window=window, q_chunk=q_chunk)
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.is_moe:
        m, aux = moe_forward(p["moe"], h, cfg)
    else:
        m, aux = mlp_forward(p["mlp"], h), 0.0
    return x + m, aux


def _rec_block(p, x, cfg: ModelConfig):
    h = apply_norm(cfg, p["ln1"], x)
    r, _ = rg.rglru_block_forward(p["rec"], h, cfg)
    x = x + r
    x = x + mlp_forward(p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x


def _attn_block(p, x, cfg: ModelConfig, positions, *, mode, window, q_chunk):
    h = apply_norm(cfg, p["ln1"], x)
    x = x + attention_forward(p["attn"], h, cfg, positions=positions,
                              mode=mode, window=window, q_chunk=q_chunk)
    x = x + mlp_forward(p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x


def _rwkv_block(p, x, cfg: ModelConfig):
    h = apply_norm(cfg, p["ln1"], x)
    zeros = jnp.zeros_like(x[:, 0])
    t, _ = rw.time_mix_forward(p["tm"], h, zeros, cfg)
    x = x + t
    h = apply_norm(cfg, p["ln2"], x)
    c, _ = rw.channel_mix_forward(p["cm"], h, zeros)
    return x + c


def _cross_block(p, x, cfg: ModelConfig, positions, enc_out, q_chunk):
    h = apply_norm(cfg, p["ln1"], x)
    x = x + attention_forward(p["attn"], h, cfg, positions=positions,
                              mode="causal", q_chunk=q_chunk)
    h = apply_norm(cfg, p["ln_x"], x)
    x = x + attention_forward(p["xattn"], h, cfg, positions=positions,
                              mode="cross", context=enc_out, q_chunk=q_chunk)
    x = x + mlp_forward(p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x


# ---------------------------------------------------------------------------
# full forward (training)
# ---------------------------------------------------------------------------

def _scan_layers(layers, x, body, cfg: ModelConfig):
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(carry, layer_p):
        x, aux = carry
        # sequence-parallel residual: the remat boundary tensor is sharded
        # over the model axis, cutting stored-activation HBM by its extent
        x = constrain(x, ("batch", "seq_resid", "embed"))
        x, a = fn(layer_p, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, 0.0), layers)
    return x, aux


def _embed(params, cfg, tokens, dtype):
    x = params["embed"].astype(dtype)[tokens]
    return constrain(x, ("batch", "seq", "embed"))


def _encoder(params, cfg: ModelConfig, frames, q_chunk):
    dtype = _compute_dtype(cfg)
    x = frames.astype(dtype) + params["enc_pos"].astype(dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(p, x):
        return _attn_block(p, x, cfg, pos, mode="bidir", window=0,
                           q_chunk=q_chunk), 0.0

    x, _ = _scan_layers(params["enc_layers"], x, body, cfg)
    return apply_norm(cfg, params["enc_norm"], x)


def backbone(params, cfg: ModelConfig, x, positions, *, enc_out=None,
             q_chunk: int = 1024):
    """Shared trunk: stacked blocks on embedded input x (B, S, D)."""
    aux = 0.0
    if cfg.rwkv:
        x = apply_norm(cfg, params["ln_in"], x)
        x, aux = _scan_layers(params["layers"], x,
                              lambda p, h: (_rwkv_block(p, h, cfg), 0.0), cfg)
    elif cfg.rglru:
        def super_body(p, h):
            def rec_step(hh, rp):
                return _rec_block(rp, hh, cfg), None
            h, _ = jax.lax.scan(rec_step, h, p["rec"])
            h = _attn_block(p["attn"], h, cfg, positions, mode="local",
                            window=cfg.window, q_chunk=q_chunk)
            return h, 0.0

        x, _ = _scan_layers(params["super"], x, super_body, cfg)
        if "tail" in params:
            def tail_body(p, h):
                return _rec_block(p, h, cfg), 0.0
            x, _ = _scan_layers(params["tail"], x, tail_body, cfg)
    elif cfg.is_encdec:
        def body(p, h):
            return _cross_block(p, h, cfg, positions, enc_out, q_chunk), 0.0
        x, _ = _scan_layers(params["layers"], x, body, cfg)
    else:
        def body(p, h):
            return _dense_block(p, h, cfg, positions, q_chunk=q_chunk)
        x, aux = _scan_layers(params["layers"], x, body, cfg)
    return apply_norm(cfg, params["final_norm"], x), aux


def output_weights(params, cfg: ModelConfig, dtype):
    if cfg.tie_embeddings:
        return params["embed"].astype(dtype).T
    return params["lm_head"].astype(dtype)


def forward_train(params, cfg: ModelConfig, batch, *, q_chunk: int = 1024,
                  xent_chunk: int = 512):
    """batch: {"tokens": (B,S) int32, "targets": (B,S) int32,
    "loss_mask": (B,S), ["frames"|"image_embeds"]: (B,T,D)}."""
    dtype = _compute_dtype(cfg)
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens, dtype)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encoder(params, cfg, batch["frames"], q_chunk)
        x = x + params["dec_pos"].astype(dtype)[None, :x.shape[1]]
    if cfg.n_image_tokens:
        img = batch["image_embeds"].astype(dtype)
        img = constrain(img, ("batch", "seq", "embed"))
        x = jnp.concatenate([img, x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    h, aux = backbone(params, cfg, x, positions, enc_out=enc_out,
                      q_chunk=q_chunk)
    if cfg.n_image_tokens:
        h = h[:, cfg.n_image_tokens:]
    w_out = output_weights(params, cfg, dtype)
    loss = chunked_xent(h, w_out, batch["targets"], batch["loss_mask"],
                        chunk=xent_chunk)
    total = loss + 0.01 * aux
    return total, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _kv_shape(cfg, b, s):
    return (b, s, cfg.n_kv_heads, cfg.head_dim)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    """Zero cache covering positions [0, cache_len)."""
    if cfg.rwkv:
        h = rw.n_heads(cfg)
        L = cfg.n_layers
        return {
            "S": jnp.zeros((L, batch, h, rw.HEAD_N, rw.HEAD_N), jnp.float32),
            "x_tm": jnp.zeros((L, batch, cfg.d_model), dtype),
            "x_cm": jnp.zeros((L, batch, cfg.d_model), dtype),
        }
    if cfg.rglru:
        n_super, n_tail = hybrid_layout(cfg)
        w = min(cfg.window, cache_len)
        cache = {
            "h": jnp.zeros((n_super, cfg.rec_per_attn, batch, cfg.lru_width),
                           jnp.float32),
            "conv": jnp.zeros((n_super, cfg.rec_per_attn, batch,
                               cfg.conv_width - 1, cfg.lru_width), dtype),
            "k": jnp.zeros((n_super, *_kv_shape(cfg, batch, w)), dtype),
            "v": jnp.zeros((n_super, *_kv_shape(cfg, batch, w)), dtype),
        }
        if n_tail:
            cache["tail_h"] = jnp.zeros((n_tail, batch, cfg.lru_width),
                                        jnp.float32)
            cache["tail_conv"] = jnp.zeros(
                (n_tail, batch, cfg.conv_width - 1, cfg.lru_width), dtype)
        return cache
    L = cfg.n_layers
    cache = {
        "k": jnp.zeros((L, *_kv_shape(cfg, batch, cache_len)), dtype),
        "v": jnp.zeros((L, *_kv_shape(cfg, batch, cache_len)), dtype),
    }
    if cfg.is_encdec:
        cache["cross_k"] = jnp.zeros((L, *_kv_shape(cfg, batch, cfg.n_frames)),
                                     dtype)
        cache["cross_v"] = jnp.zeros((L, *_kv_shape(cfg, batch, cfg.n_frames)),
                                     dtype)
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B, 1) int32; pos: absolute position, scalar int32 or a
    per-row (B,) int32 vector (continuous batching: each batch slot decodes
    at its own position).  Returns (logits (B, V) fp32, new_cache)."""
    dtype = _compute_dtype(cfg)
    x = _embed(params, cfg, tokens, dtype)
    if cfg.is_encdec:
        if jnp.ndim(pos) > 0:
            x = x + jnp.take(params["dec_pos"].astype(dtype),
                             jnp.reshape(pos, (-1,)), axis=0)[:, None]
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["dec_pos"].astype(dtype), pos, 1, 0)[None]

    if cfg.rwkv:
        def step(x, inp):
            p, S, x_tm, x_cm = inp
            h = apply_norm(cfg, p["ln1"], x)
            t, (S2, x_tm2) = rw.time_mix_decode(p["tm"], h, (S, x_tm), cfg)
            x = x + t
            h = apply_norm(cfg, p["ln2"], x)
            c, x_cm2 = rw.channel_mix_decode(p["cm"], h, x_cm)
            return x + c, (S2, x_tm2.astype(x_tm.dtype),
                           x_cm2.astype(x_cm.dtype))

        x0 = apply_norm(cfg, params["ln_in"], x)
        x_out, (S_new, xtm_new, xcm_new) = jax.lax.scan(
            step, x0, (params["layers"], cache["S"], cache["x_tm"],
                       cache["x_cm"]))
        new_cache = {"S": S_new, "x_tm": xtm_new, "x_cm": xcm_new}
        h = apply_norm(cfg, params["final_norm"], x_out)
    elif cfg.rglru:
        def super_step(x, inp):
            p, hs, convs, k, v = inp

            def rec_step(x, rin):
                rp, h0, c0 = rin
                hh = apply_norm(cfg, rp["ln1"], x)
                r, st = rg.rglru_block_decode(rp["rec"], hh,
                                              {"h": h0, "conv": c0}, cfg)
                x = x + r
                x = x + mlp_forward(rp["mlp"],
                                    apply_norm(cfg, rp["ln2"], x))
                return x, (st["h"], st["conv"])

            x, (h_new, c_new) = jax.lax.scan(rec_step, x,
                                             (p["rec"], hs, convs))
            ap = p["attn"]
            hh = apply_norm(cfg, ap["ln1"], x)
            a, kv_new = attention_decode(ap["attn"], hh, {"k": k, "v": v},
                                         cfg, pos=pos, window=cfg.window)
            x = x + a
            x = x + mlp_forward(ap["mlp"], apply_norm(cfg, ap["ln2"], x))
            return x, (h_new, c_new, kv_new["k"], kv_new["v"])

        x, (h_new, c_new, k_new, v_new) = jax.lax.scan(
            super_step, x, (params["super"], cache["h"], cache["conv"],
                            cache["k"], cache["v"]))
        new_cache = dict(cache, h=h_new, conv=c_new, k=k_new, v=v_new)
        if "tail" in params:
            def tail_step(x, inp):
                rp, h0, c0 = inp
                hh = apply_norm(cfg, rp["ln1"], x)
                r, st = rg.rglru_block_decode(rp["rec"], hh,
                                              {"h": h0, "conv": c0}, cfg)
                x = x + r
                x = x + mlp_forward(rp["mlp"], apply_norm(cfg, rp["ln2"], x))
                return x, (st["h"], st["conv"])

            x, (th, tc) = jax.lax.scan(tail_step, x,
                                       (params["tail"], cache["tail_h"],
                                        cache["tail_conv"]))
            new_cache.update(tail_h=th, tail_conv=tc)
        h = apply_norm(cfg, params["final_norm"], x)
    elif cfg.is_encdec:
        def step(x, inp):
            p, k, v, xk, xv = inp
            hh = apply_norm(cfg, p["ln1"], x)
            a, kv_new = attention_decode(p["attn"], hh, {"k": k, "v": v},
                                         cfg, pos=pos)
            x = x + a
            hh = apply_norm(cfg, p["ln_x"], x)
            ax, _ = attention_decode(p["xattn"], hh, None, cfg, pos=pos,
                                     cross_kv=(xk, xv))
            x = x + ax
            x = x + mlp_forward(p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, (kv_new["k"], kv_new["v"])

        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, k=k_new, v=v_new)
        h = apply_norm(cfg, params["final_norm"], x)
    else:
        def step(x, inp):
            p, k, v = inp
            if cfg.block_type == "parallel":
                hh = apply_norm(cfg, p["ln1"], x)
                a, kv_new = attention_decode(p["attn"], hh, {"k": k, "v": v},
                                             cfg, pos=pos)
                if cfg.is_moe:
                    m, _ = moe_forward(p["moe"], hh, cfg)
                else:
                    m = mlp_forward(p["mlp"], hh)
                x = x + a + m
            else:
                hh = apply_norm(cfg, p["ln1"], x)
                a, kv_new = attention_decode(p["attn"], hh, {"k": k, "v": v},
                                             cfg, pos=pos)
                x = x + a
                hh = apply_norm(cfg, p["ln2"], x)
                if cfg.is_moe:
                    m, _ = moe_forward(p["moe"], hh, cfg)
                else:
                    m = mlp_forward(p["mlp"], hh)
                x = x + m
            return x, (kv_new["k"], kv_new["v"])

        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=k_new, v=v_new)
        h = apply_norm(cfg, params["final_norm"], x)

    w_out = output_weights(params, cfg, dtype)
    logits = (h[:, 0] @ w_out).astype(jnp.float32)
    logits = constrain(logits, ("batch", "vocab"))
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: run the prompt through the trunk and build the decode cache
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, cache_len: int, *,
            q_chunk: int = 1024, last_idx=None):
    """batch: {"tokens": (B, S)} (+ "frames" for enc-dec).  Returns
    (last-token logits (B, V) fp32, cache primed for position S).

    ``last_idx`` (optional (B,) int32) selects a per-row logits position
    instead of ``S - 1`` — used by the continuous-batching engine, which
    right-pads prompts up to a bucket length and needs the logits of each
    row's *true* last prompt token.  (Causality guarantees right padding
    cannot influence positions ``<= last_idx``; the decode loop overwrites
    each padded KV entry at position ``p`` before the mask first admits it.)
    """
    dtype = _compute_dtype(cfg)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = _embed(params, cfg, tokens, dtype)
    if cfg.n_image_tokens:
        img = batch["image_embeds"].astype(dtype)
        x = jnp.concatenate([constrain(img, ("batch", "seq", "embed")), x], 1)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    cache = init_cache(cfg, b, cache_len, dtype=dtype)

    if cfg.rwkv:
        x = apply_norm(cfg, params["ln_in"], x)

        def step(x, p):
            h = apply_norm(cfg, p["ln1"], x)
            zeros = jnp.zeros_like(x[:, 0])
            t, (S_fin, x_tm) = rw.time_mix_forward(p["tm"], h, zeros, cfg)
            x = x + t
            h = apply_norm(cfg, p["ln2"], x)
            c, x_cm = rw.channel_mix_forward(p["cm"], h, zeros)
            return x + c, (S_fin, x_tm.astype(dtype), x_cm.astype(dtype))

        fn = jax.checkpoint(step) if cfg.remat else step
        x, (S_new, xtm, xcm) = jax.lax.scan(fn, x, params["layers"])
        cache = {"S": S_new, "x_tm": xtm, "x_cm": xcm}
        h = apply_norm(cfg, params["final_norm"], x)
    elif cfg.rglru:
        w = min(cfg.window, cache_len)
        slots = jnp.arange(s - w, s) % w if s >= w else jnp.arange(s)

        def rec_run(rp, x):
            h = apply_norm(cfg, rp["ln1"], x)
            r, st = rg.rglru_block_forward(rp["rec"], h, cfg,
                                           return_state=True)
            x = x + r
            x = x + mlp_forward(rp["mlp"], apply_norm(cfg, rp["ln2"], x))
            return x, st

        def super_step(x, p):
            def rec_step(xx, rp):
                return rec_run(rp, xx)
            x, sts = jax.lax.scan(rec_step, x, p["rec"])
            ap = p["attn"]
            hh = apply_norm(cfg, ap["ln1"], x)
            a, kv = attention_forward(ap["attn"], hh, cfg,
                                      positions=positions, mode="local",
                                      window=cfg.window, q_chunk=q_chunk,
                                      return_kv=True)
            x = x + a
            x = x + mlp_forward(ap["mlp"], apply_norm(cfg, ap["ln2"], x))
            k_c = jnp.zeros(_kv_shape(cfg, b, w), dtype).at[:, slots].set(
                kv[0][:, -w:].astype(dtype) if s >= w else kv[0].astype(dtype))
            v_c = jnp.zeros(_kv_shape(cfg, b, w), dtype).at[:, slots].set(
                kv[1][:, -w:].astype(dtype) if s >= w else kv[1].astype(dtype))
            return x, (sts["h"], sts["conv"], k_c, v_c)

        fn = jax.checkpoint(super_step) if cfg.remat else super_step
        x, (hs, convs, ks, vs) = jax.lax.scan(fn, x, params["super"])
        cache.update(h=hs, conv=convs, k=ks, v=vs)
        if "tail" in params:
            def tail_step(x, rp):
                return rec_run(rp, x)
            fn = jax.checkpoint(tail_step) if cfg.remat else tail_step
            x, sts = jax.lax.scan(fn, x, params["tail"])
            cache.update(tail_h=sts["h"], tail_conv=sts["conv"])
        h = apply_norm(cfg, params["final_norm"], x)
    else:
        enc_out = None
        if cfg.is_encdec:
            enc_out = _encoder(params, cfg, batch["frames"], q_chunk)
            x = x + params["dec_pos"].astype(dtype)[None, :s]

        def dense_step(x, p):
            hh = apply_norm(cfg, p["ln1"], x)
            a, kv = attention_forward(p["attn"], hh, cfg,
                                      positions=positions, mode="causal",
                                      q_chunk=q_chunk, return_kv=True)
            if cfg.block_type == "parallel":
                if cfg.is_moe:
                    m, _ = moe_forward(p["moe"], hh, cfg)
                else:
                    m = mlp_forward(p["mlp"], hh)
                x = x + a + m
            else:
                x = x + a
                hh2 = apply_norm(cfg, p["ln2"], x)
                if cfg.is_moe:
                    m, _ = moe_forward(p["moe"], hh2, cfg)
                else:
                    m = mlp_forward(p["mlp"], hh2)
                x = x + m
            k_c = jnp.zeros(_kv_shape(cfg, b, cache_len), dtype)
            k_c = jax.lax.dynamic_update_slice(k_c, kv[0].astype(dtype),
                                               (0, 0, 0, 0))
            v_c = jnp.zeros(_kv_shape(cfg, b, cache_len), dtype)
            v_c = jax.lax.dynamic_update_slice(v_c, kv[1].astype(dtype),
                                               (0, 0, 0, 0))
            return x, (k_c, v_c)

        def encdec_step(x, p):
            hh = apply_norm(cfg, p["ln1"], x)
            a, kv = attention_forward(p["attn"], hh, cfg,
                                      positions=positions, mode="causal",
                                      q_chunk=q_chunk, return_kv=True)
            x = x + a
            hh = apply_norm(cfg, p["ln_x"], x)
            ax, xkv = attention_forward(p["xattn"], hh, cfg,
                                        positions=positions, mode="cross",
                                        context=enc_out, q_chunk=q_chunk,
                                        return_kv=True)
            x = x + ax
            x = x + mlp_forward(p["mlp"], apply_norm(cfg, p["ln2"], x))
            k_c = jnp.zeros(_kv_shape(cfg, b, cache_len), dtype)
            k_c = jax.lax.dynamic_update_slice(k_c, kv[0].astype(dtype),
                                               (0, 0, 0, 0))
            v_c = jnp.zeros(_kv_shape(cfg, b, cache_len), dtype)
            v_c = jax.lax.dynamic_update_slice(v_c, kv[1].astype(dtype),
                                               (0, 0, 0, 0))
            return x, (k_c, v_c, xkv[0].astype(dtype), xkv[1].astype(dtype))

        if cfg.is_encdec:
            fn = jax.checkpoint(encdec_step) if cfg.remat else encdec_step
            x, (ks, vs, xks, xvs) = jax.lax.scan(fn, x, params["layers"])
            cache.update(k=ks, v=vs, cross_k=xks, cross_v=xvs)
        else:
            fn = jax.checkpoint(dense_step) if cfg.remat else dense_step
            x, (ks, vs) = jax.lax.scan(fn, x, params["layers"])
            cache.update(k=ks, v=vs)
        h = apply_norm(cfg, params["final_norm"], x)

    w_out = output_weights(params, cfg, dtype)
    h_last = h[:, -1] if last_idx is None else h[jnp.arange(b), last_idx]
    logits = (h_last @ w_out).astype(jnp.float32)
    return constrain(logits, ("batch", "vocab")), cache
