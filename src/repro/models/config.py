"""Model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # block wiring
    block_type: str = "llama"   # llama | parallel (cohere)
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    mlp_type: str = "swiglu"    # swiglu | gelu (whisper)
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid (RecurrentGemma): blocks cycle [recurrent]*rec_per_attn + [attn]
    rglru: bool = False
    rec_per_attn: int = 2
    window: int = 0             # local-attention window (0 = full)
    conv_width: int = 4
    lru_width: int = 0          # 0 -> d_model

    # attention-free linear recurrence (RWKV-6 "Finch")
    rwkv: bool = False

    # encoder-decoder (Whisper): n_layers = decoder layers
    encoder_layers: int = 0
    n_frames: int = 1500        # audio frontend stub sequence length
    max_decode_len: int = 32768  # learned decoder position table size

    # VLM (LLaVA-NeXT): precomputed patch embeddings prepended to text
    n_image_tokens: int = 0

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rglru and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.n_heads and not self.rwkv:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
                "q heads must be divisible by kv heads (GQA)"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd if not self.rwkv else 0
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.rwkv:
            # time-mix (r,k,v,g,o + decay LoRA) + channel-mix
            attn = 5 * d * d
            mlp = 3 * d * ff
        elif self.is_moe:
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts
        else:
            mlp = 3 * d * ff
        per_layer = attn + mlp + 2 * d
        total = self.n_layers * per_layer
        if self.rglru:
            w = self.lru_width
            rec_block = d * w * 2 + w * self.conv_width + 3 * w + w * d + 3 * d * ff
            n_attn = self.n_layers // (self.rec_per_attn + 1)
            n_rec = self.n_layers - n_attn
            total = n_rec * rec_block + n_attn * per_layer
        if self.is_encdec:
            enc = self.encoder_layers * (attn + 3 * d * ff + 2 * d)
            dec_cross = self.n_layers * (d * n_q + 2 * d * n_kv + n_q * d)
            total += enc + dec_cross
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * ff
        return int(dense + self.n_layers * self.top_k * 3 * d * ff)
