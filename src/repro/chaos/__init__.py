"""repro.chaos — deterministic multi-fault injection with record/replay.

The paper's premise is surviving "precarious environments", but a single
fault class (whole-host crash) exercises only one recovery path.  This
package widens the fault model into a *taxonomy* — see
:mod:`repro.chaos.faults` for the class-by-class list and the recovery path
each one exercises — and makes every chaos run exactly reproducible:

* **Taxonomy**: ``host_crash``, ``slowdown`` (straggler), ``capacity_loss``
  (k workers down for an MTTR window), ``ckpt_corrupt`` (torn training
  checkpoint shard), ``snapshot_corrupt`` (corrupt decode snapshot),
  ``nan_poison`` (NaN/Inf train-step output), ``net_partition`` (split-brain
  between ``repro.ft.crosspod`` pods: quorum trains on, minority parks and
  catches up from the quorum's checkpoint on heal), and ``disk_full``
  (checkpoint save hits ENOSPC mid-write: the store prunes its oldest
  committed indices and retries without ever corrupting the committed
  index).
* **Record**: ``sample_trace(profile, horizon=..., seed=...)`` draws a
  :class:`~repro.chaos.faults.FaultTrace` from the Section 4.1 Weibull/
  log-normal distributions (per-class MTBF scaled by the stable / normal /
  unstable profile) and ``trace.save(path)`` serializes it to JSON.
* **Replay**: ``FaultTrace.load(path)`` + :class:`ChaosEngine` re-fires the
  exact same events — every event carries its own step, targets, duration,
  and corruption seed, so no RNG runs at replay time and two runs of
  ``benchmarks/chaos_matrix.py`` over one trace produce identical grids.

Consumers: ``repro.ft.coordinator.TrainingCoordinator(chaos=...)`` and
``repro.serve.ServeEngine(chaos=...)`` accept a :class:`ChaosEngine`;
``launch/train.py`` and ``launch/serve.py`` expose it as ``--chaos PROFILE``
/ ``--chaos-record PATH`` / ``--chaos-trace PATH``.
"""
from .faults import (CAPACITY_LOSS, CHAOS_PROFILES, CKPT_CORRUPT,
                     DISK_FULL, FAULT_KINDS, HOST_CRASH, NAN_POISON,
                     NET_PARTITION, SERVE_KINDS, SLOWDOWN, SNAPSHOT_CORRUPT,
                     TRACE_VERSION, TRAIN_KINDS, ChaosEngine, FaultEvent,
                     FaultTrace, corrupt_checkpoint_shard, flip_bytes,
                     sample_trace)

__all__ = [
    "CAPACITY_LOSS",
    "CHAOS_PROFILES",
    "CKPT_CORRUPT",
    "ChaosEngine",
    "DISK_FULL",
    "FAULT_KINDS",
    "FaultEvent",
    "FaultTrace",
    "HOST_CRASH",
    "NAN_POISON",
    "NET_PARTITION",
    "SERVE_KINDS",
    "SLOWDOWN",
    "SNAPSHOT_CORRUPT",
    "TRACE_VERSION",
    "TRAIN_KINDS",
    "corrupt_checkpoint_shard",
    "flip_bytes",
    "sample_trace",
]
