"""Fault taxonomy, deterministic sampling, and record/replay traces.

The taxonomy — one constant per fault class, ``FAULT_KINDS`` is the full
list.  Each class maps to exactly one recovery path owned by one layer:

=================== ========================================= ==============
class               recovery path                             owning layer
=================== ========================================= ==============
``host_crash``      checkpoint/snapshot restore +             serve + train
                    resubmission (the paper's original
                    fault model)
``slowdown``        stalled decode slots resume where they    serve + train
                    left off / virtual-time straggler
                    penalty; no state lost
``capacity_loss``   deadline-aware load shedding plus         serve
                    queue-length-priced admission
                    (reject-on-arrival with ``retry_after``)
                    keep the queue bounded; training treats
                    it as an outage window
``ckpt_corrupt``    ``CheckpointStore.restore`` quarantines   train
                    the bad shard and falls back to the
                    newest checkpoint whose shards verify
``snapshot_corrupt`` checksum mismatch detected at resume;    serve
                    the request re-prefills from scratch
``nan_poison``      the coordinator's NaN guard rejects the   train
                    update and quarantines the poisoned
                    batch index
``net_partition``   the majority pod component (quorum)       train (crosspod)
                    keeps training on its own averaged
                    gradients, minority pods park; on heal
                    stale pods restore the quorum's latest
                    committed checkpoint with error-feedback
                    residuals reset (no compression-bias
                    leak across the partition)
``disk_full``       the async checkpoint ``_write`` hits      train (ckpt
                    ENOSPC mid-save; the store prunes the     store)
                    oldest committed indices and retries —
                    the atomic pointer flip means the
                    committed index is never corrupted
=================== ========================================= ==============

``net_partition`` events carry the *minority* pod set as ``targets`` and the
partition window as ``duration``; ``disk_full`` events arm the next
checkpoint save with an injected ENOSPC.

Trace format (``FaultTrace.to_json``)::

    {"version": 1,
     "meta": {"profile": "unstable", "seed": 0, "horizon": 400,
              "n_targets": 4},
     "events": [{"step": 17, "kind": "host_crash", "targets": [2],
                 "duration": 12, "seed": 1234567}, ...]}

Every event is fully explicit — step, kind, targets, duration, and a
per-event RNG seed that pins which bytes a corruption flips — so replaying a
trace through :class:`ChaosEngine` reproduces a chaos run *bit-identically*.
To reproduce a recorded run::

    trace = FaultTrace.load("chaos_trace.json")
    engine = ServeEngine(cfg, ecfg, pool=pool, chaos=ChaosEngine(trace), ...)

or from the CLI: ``python -m repro.launch.serve --chaos-trace chaos.json``.
``sample_trace`` draws inter-arrival gaps per fault class from the paper's
Section 4.1 Weibull MTBF and log-normal MTTR distributions (in step units,
scaled per :data:`CHAOS_PROFILES` environment), entirely from one seed.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os

import numpy as np

from repro.obs.trace import NULL_TRACER

__all__ = [
    "HOST_CRASH",
    "SLOWDOWN",
    "CAPACITY_LOSS",
    "CKPT_CORRUPT",
    "SNAPSHOT_CORRUPT",
    "NAN_POISON",
    "NET_PARTITION",
    "DISK_FULL",
    "FAULT_KINDS",
    "SERVE_KINDS",
    "TRAIN_KINDS",
    "TRACE_VERSION",
    "CHAOS_PROFILES",
    "FaultEvent",
    "FaultTrace",
    "ChaosEngine",
    "sample_trace",
    "flip_bytes",
    "corrupt_checkpoint_shard",
]

HOST_CRASH = "host_crash"
SLOWDOWN = "slowdown"
CAPACITY_LOSS = "capacity_loss"
CKPT_CORRUPT = "ckpt_corrupt"
SNAPSHOT_CORRUPT = "snapshot_corrupt"
NAN_POISON = "nan_poison"
NET_PARTITION = "net_partition"
DISK_FULL = "disk_full"

FAULT_KINDS = (HOST_CRASH, SLOWDOWN, CAPACITY_LOSS, CKPT_CORRUPT,
               SNAPSHOT_CORRUPT, NAN_POISON, NET_PARTITION, DISK_FULL)
#: kinds each layer consumes (the other layer's kinds are no-ops there)
SERVE_KINDS = (HOST_CRASH, SLOWDOWN, CAPACITY_LOSS, SNAPSHOT_CORRUPT)
TRAIN_KINDS = (HOST_CRASH, SLOWDOWN, CAPACITY_LOSS, CKPT_CORRUPT, NAN_POISON,
               NET_PARTITION, DISK_FULL)

TRACE_VERSION = 1

# Per-class MTBF in steps, mirroring repro.serve.replicas.SERVE_ENVIRONMENTS:
# stability drops -> every fault class strikes more often and repairs slower.
CHAOS_PROFILES: dict[str, dict] = {
    "stable": {
        "shape": 12.5, "mttr_steps": 8,
        "mtbf": {HOST_CRASH: 800.0, SLOWDOWN: 600.0, CAPACITY_LOSS: 4000.0,
                 SNAPSHOT_CORRUPT: 3000.0, CKPT_CORRUPT: 3000.0,
                 NAN_POISON: 2500.0, NET_PARTITION: 5000.0,
                 DISK_FULL: 6000.0},
    },
    "normal": {
        "shape": 12.0, "mttr_steps": 16,
        "mtbf": {HOST_CRASH: 200.0, SLOWDOWN: 150.0, CAPACITY_LOSS: 1000.0,
                 SNAPSHOT_CORRUPT: 800.0, CKPT_CORRUPT: 800.0,
                 NAN_POISON: 600.0, NET_PARTITION: 1500.0, DISK_FULL: 2000.0},
    },
    "unstable": {
        "shape": 11.5, "mttr_steps": 24,
        "mtbf": {HOST_CRASH: 30.0, SLOWDOWN: 45.0, CAPACITY_LOSS: 150.0,
                 SNAPSHOT_CORRUPT: 120.0, CKPT_CORRUPT: 120.0,
                 NAN_POISON: 90.0, NET_PARTITION: 200.0, DISK_FULL: 250.0},
    },
}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  Fully explicit so replay needs no RNG."""

    step: int
    kind: str
    targets: tuple[int, ...] = ()
    duration: int = 0
    seed: int = 0

    def to_json(self) -> dict:
        return {"step": self.step, "kind": self.kind,
                "targets": list(self.targets), "duration": self.duration,
                "seed": self.seed}

    @classmethod
    def from_json(cls, d: dict) -> "FaultEvent":
        kind = str(d["kind"])
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in trace event {d!r}; "
                f"known kinds: {', '.join(FAULT_KINDS)}")
        return cls(step=int(d["step"]), kind=kind,
                   targets=tuple(int(t) for t in d.get("targets", ())),
                   duration=int(d.get("duration", 0)),
                   seed=int(d.get("seed", 0)))


@dataclasses.dataclass
class FaultTrace:
    """An ordered, serializable fault schedule (the record/replay unit)."""

    events: list[FaultEvent]
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.events)

    def kinds(self) -> set[str]:
        return {ev.kind for ev in self.events}

    def to_json(self) -> dict:
        return {"version": TRACE_VERSION, "meta": self.meta,
                "events": [ev.to_json() for ev in self.events]}

    @classmethod
    def from_json(cls, d: dict) -> "FaultTrace":
        version = d.get("version", TRACE_VERSION)
        if version != TRACE_VERSION:
            raise ValueError(
                f"unsupported trace 'version' field: {version!r} (this "
                f"build replays version {TRACE_VERSION} traces only)")
        return cls(events=[FaultEvent.from_json(e) for e in d["events"]],
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "FaultTrace":
        with open(path) as f:
            return cls.from_json(json.load(f))


def sample_trace(profile: str | dict, *, horizon: int, n_targets: int = 1,
                 seed: int = 0, kinds: tuple[str, ...] | None = None
                 ) -> FaultTrace:
    """Deterministically sample a :class:`FaultTrace` from one seed.

    Per fault class, inter-arrival gaps are Weibull with the profile's
    per-class MTBF scale (paper Section 4.1); outage/slowdown durations are
    log-normal around the profile's MTTR.  ``kinds`` restricts sampling to a
    subset of the taxonomy (e.g. one cell of the chaos matrix).
    """
    spec = CHAOS_PROFILES[profile] if isinstance(profile, str) else profile
    rng = np.random.default_rng(seed)
    events: list[FaultEvent] = []
    for kind in (kinds or FAULT_KINDS):
        mtbf = float(spec["mtbf"].get(kind, 0.0))
        if mtbf <= 0:
            continue
        t = rng.uniform(0, mtbf)
        while t < horizon:
            dur = max(1, int(round(rng.lognormal(
                np.log(max(spec["mttr_steps"], 1.0)), 0.25))))
            k = 1
            if kind == CAPACITY_LOSS and n_targets > 1:
                k = int(rng.integers(1, n_targets))
            elif kind == NET_PARTITION:
                # targets = the minority pod set: strictly less than half the
                # pods, so the complement always holds quorum
                max_k = max(1, (n_targets - 1) // 2)
                k = 1 if max_k == 1 else int(rng.integers(1, max_k + 1))
            targets = tuple(sorted(
                rng.choice(max(n_targets, 1), size=min(k, max(n_targets, 1)),
                           replace=False).tolist()))
            events.append(FaultEvent(
                step=int(t), kind=kind, targets=targets, duration=dur,
                seed=int(rng.integers(0, 2**31 - 1))))
            t += max(1.0, mtbf * rng.weibull(spec["shape"]))
    events.sort(key=lambda e: (e.step, e.kind, e.targets))
    meta = {"profile": profile if isinstance(profile, str) else "custom",
            "seed": seed, "horizon": horizon, "n_targets": n_targets,
            "kinds": list(kinds or FAULT_KINDS)}
    return FaultTrace(events=events, meta=meta)


class ChaosEngine:
    """Replays a :class:`FaultTrace` against a training or serving run.

    The consumer (``TrainingCoordinator`` / ``ServeEngine``) calls
    :meth:`events_at` once per step; each event fires exactly once, in trace
    order, so two runs over the same trace see identical fault sequences.

    A ``tracer`` (``repro.obs``) annotates every injected fault as a
    ``fault.<kind>`` span event and arms the flight recorder's
    dump-on-fault trigger; the default NULL tracer makes this one branch.
    """

    def __init__(self, trace: FaultTrace, *, tracer=None):
        self.trace = trace
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._by_step: dict[int, list[FaultEvent]] = {}
        for ev in trace.events:
            self._by_step.setdefault(ev.step, []).append(ev)
        self.applied: list[FaultEvent] = []
        self.applied_by_kind: collections.Counter = collections.Counter()

    def events_at(self, step: int) -> list[FaultEvent]:
        evs = self._by_step.pop(step, [])
        self.applied.extend(evs)
        for ev in evs:
            self.applied_by_kind[ev.kind] += 1
            self.tracer.fault(ev.kind, step=step,
                              targets=list(ev.targets),
                              duration=ev.duration)
        return evs

    def pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())


# -- corruption helpers (byte-level, seed-deterministic) ---------------------
def flip_bytes(path: str, seed: int, n: int = 1) -> bool:
    """XOR-flip ``n`` payload bytes of ``path`` (skipping any format header
    region by flipping in the back half).  Returns False on an empty file."""
    with open(path, "r+b") as f:
        data = bytearray(f.read())
        if not data:
            return False
        rng = np.random.default_rng(seed)
        lo = len(data) // 2
        for _ in range(n):
            data[int(rng.integers(lo, len(data)))] ^= 0xFF
        f.seek(0)
        f.write(data)
        f.truncate()
    return True


def corrupt_checkpoint_shard(store, seed: int) -> str | None:
    """Flip bytes in one shard of the *newest* committed checkpoint of a
    ``repro.ft.checkpoint.CheckpointStore``.  Victim selection is a pure
    function of ``seed``.  Returns the corrupted path (None if no commit)."""
    steps = store.committed_steps()
    if not steps:
        return None
    index = store.read_index(steps[-1])
    names = sorted(index["leaves"])
    if not names:
        return None
    meta = index["leaves"][names[seed % len(names)]]
    if not os.path.exists(meta["file"]):
        return None
    flip_bytes(meta["file"], seed)
    return meta["file"]
