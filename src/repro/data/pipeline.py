"""Deterministic, shardable, resumable synthetic-token pipeline.

Design goals mirrored from production data loaders:

* **Deterministic**: batch ``i`` is a pure function of (seed, i) -- any host
  can regenerate any shard, which is what makes CRCH-style *speculative
  shard replication* (ft/straggler.py) free of coordination: two hosts
  computing the same shard produce identical tokens.
* **Shardable**: ``shard(host, n_hosts)`` views are disjoint slices of the
  global batch.
* **Resumable**: the full iterator state is one integer (``next_index``),
  stored in the checkpoint global index -- the paper's "light-weight program
  state".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


class SyntheticTokenPipeline:
    """Zipf-ish synthetic LM batches with next-token targets."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig, *,
                 start_index: int = 0):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.next_index = int(start_index)

    # -- state (checkpointable) ---------------------------------------------
    def state(self) -> dict:
        return {"next_index": self.next_index, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: DataConfig, model_cfg: ModelConfig,
                   state: dict) -> "SyntheticTokenPipeline":
        assert state["seed"] == cfg.seed, "seed mismatch on restore"
        return cls(cfg, model_cfg, start_index=state["next_index"])

    # -- batch generation ----------------------------------------------------
    def _tokens(self, index: int, rows: slice) -> np.ndarray:
        b = self.cfg.global_batch
        s = self.cfg.seq_len
        v = self.model_cfg.vocab_size
        rng = np.random.default_rng((self.cfg.seed, index))
        # Zipf-like marginal with a deterministic per-row offset pattern
        raw = rng.zipf(1.3, size=(b, s + 1)) % v
        return raw.astype(np.int32)[rows]

    def batch_at(self, index: int, *, host: int = 0, n_hosts: int = 1) -> dict:
        b = self.cfg.global_batch
        assert b % n_hosts == 0
        rows = slice(host * b // n_hosts, (host + 1) * b // n_hosts)
        tok = self._tokens(index, rows)
        out = {
            "tokens": tok[:, :-1],
            "targets": tok[:, 1:],
            "loss_mask": np.ones((tok.shape[0], tok.shape[1] - 1),
                                 np.float32),
        }
        mc = self.model_cfg
        rng = np.random.default_rng((self.cfg.seed, index, 7))
        if mc.is_encdec:
            out["frames"] = rng.standard_normal(
                (tok.shape[0], mc.n_frames, mc.d_model)).astype(np.float32)
        if mc.n_image_tokens:
            out["image_embeds"] = rng.standard_normal(
                (tok.shape[0], mc.n_image_tokens, mc.d_model)
            ).astype(np.float32)
        return out

    def __next__(self) -> dict:
        batch = self.batch_at(self.next_index)
        self.next_index += 1
        return batch

    def __iter__(self):
        return self
