"""Int8 gradient compression with error feedback (distributed-optimization).

For the cross-pod (DCN) gradient reduction the pod axis is slow; compressing
gradients to int8 with per-tensor scales cuts DCN bytes 4x vs fp32 (2x vs
bf16).  Error feedback accumulates the quantization residual locally so the
compression bias vanishes over steps (Seide et al.; Karimireddy et al.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array):
    """Returns (q int8, scale f32). Symmetric per-tensor quantization."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree_with_feedback(grads, residuals):
    """Quantize grads + residuals; returns (quantized, scales, new_residuals)."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = compress_int8(target)
        deq = decompress_int8(q, s)
        return q, s, target - deq

    flat = jax.tree.map(one, grads, residuals)
    q = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return q, s, r


def decompress_tree(q, s):
    return jax.tree.map(decompress_int8, q, s)
