"""AdamW in pure JAX pytrees (no optax dependency in this container).

Moments are stored in fp32 and sharded exactly like their parameters (the
sharding rules treat the optimizer state as two more copies of the param
tree), which is what makes the ZeRO-style ``fsdp`` axis effective.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, *, master: bool = False):
    """``master=True`` keeps an fp32 master copy in the optimizer state
    (used when the live params are bf16; ZeRO-1 shards mu/nu/master)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalar gains."""
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    leaf = str(names[-1]) if names else ""
    return not any(s in leaf for s in ("scale", "bias", "ln_", "lam", "ww",
                                       "mu", "u"))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    has_master = "master" in state
    masters = state.get("master", params)

    def upd(path, p, g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        update = (mu2 / b1c) / (jnp.sqrt(nu2 / b2c) + cfg.eps)
        src = m.astype(jnp.float32)
        if _decay_mask(path):
            update = update + cfg.weight_decay * src
        m2 = src - lr * update
        return m2.astype(p.dtype), mu2, nu2, m2

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu, m: upd(path, p, g, mu, nu, m),
        params, grads, state["mu"], state["nu"], masters)
    is_tup = lambda t: isinstance(t, tuple)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is_tup)
    new_state = {
        "mu": jax.tree.map(lambda t: t[1], flat, is_leaf=is_tup),
        "nu": jax.tree.map(lambda t: t[2], flat, is_leaf=is_tup),
        "step": step,
    }
    if has_master:
        new_state["master"] = jax.tree.map(lambda t: t[3], flat,
                                           is_leaf=is_tup)
    return new_params, new_state, {"grad_norm": gnorm}
