"""HEFT with replication over-provisioning (paper Algorithm 2).

* Originals are ranked by B-level (upward rank) and placed with the classic
  insertion-based earliest-finish-time rule of Topcuoglu et al. [13].
* Replicas of a task t' are placed once *all children of t'* have been
  scheduled (Algorithm 2 lines 7-9, following Zhang et al. [8]: "replicas for
  a task are scheduled after its children"), each on the distinct VM giving
  the minimum EST insertion slot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .features import b_levels
from .workflow import CloudEnvironment, Workflow

__all__ = ["Placement", "Schedule", "heft_schedule"]


@dataclasses.dataclass
class Placement:
    task: int
    copy: int          # 0 = original, >=1 replicas
    vm: int
    est: float
    eft: float

    @property
    def is_replica(self) -> bool:
        return self.copy > 0

    @property
    def duration(self) -> float:
        return self.eft - self.est


@dataclasses.dataclass
class Schedule:
    workflow: Workflow
    env: CloudEnvironment
    placements: list[Placement]
    ranks: np.ndarray

    def __post_init__(self):
        self.by_task: dict[int, list[Placement]] = {}
        self.by_vm: dict[int, list[Placement]] = {v: [] for v in range(self.env.n_vms)}
        for p in self.placements:
            self.by_task.setdefault(p.task, []).append(p)
            self.by_vm[p.vm].append(p)
        for v in self.by_vm:
            self.by_vm[v].sort(key=lambda p: p.est)
        for t in self.by_task:
            self.by_task[t].sort(key=lambda p: p.copy)

    @property
    def makespan(self) -> float:
        """TET_perfect, Eq. (7)."""
        return max((p.eft for p in self.placements if p.copy == 0), default=0.0)

    def original(self, task: int) -> Placement:
        return self.by_task[task][0]

    def critical_path(self) -> list[int]:
        """Backtrack from argmax EFT through zero-slack predecessors (3.2)."""
        orig = {t: self.original(t) for t in self.by_task}
        t_cur = max(orig, key=lambda t: orig[t].eft)
        path = [t_cur]
        while self.workflow.parents[t_cur]:
            best_p, best_fin = None, -np.inf
            p_cur = orig[t_cur]
            for par, d in self.workflow.parents[t_cur]:
                pp = orig[par]
                fin = pp.eft + self.env.transfer_time(d, pp.vm, p_cur.vm)
                if fin > best_fin:
                    best_fin, best_p = fin, par
            path.append(best_p)
            t_cur = best_p
        path.reverse()
        return path


class _VMTimeline:
    """Busy intervals per VM with insertion-based free-slot search."""

    def __init__(self, n_vms: int):
        self.busy: list[list[tuple[float, float]]] = [[] for _ in range(n_vms)]

    def earliest_slot(self, vm: int, ready: float, duration: float) -> float:
        t = ready
        for (s, e) in self.busy[vm]:
            if t + duration <= s:
                break
            t = max(t, e)
        return t

    def append_slot(self, vm: int, ready: float) -> float:
        """EST with no insertion: after everything already scheduled."""
        last_end = self.busy[vm][-1][1] if self.busy[vm] else 0.0
        return max(ready, last_end)

    def insert(self, vm: int, start: float, end: float) -> None:
        iv = self.busy[vm]
        lo = 0
        while lo < len(iv) and iv[lo][0] < start:
            lo += 1
        iv.insert(lo, (start, end))


def heft_schedule(wf: Workflow, env: CloudEnvironment,
                  rep_counts: np.ndarray | int = 1) -> Schedule:
    """Build the over-provisioned HEFT schedule.

    ``rep_counts[t]`` = total copies of task t (1 = original only); an int
    applies uniformly (``ReplicateAll(k)`` uses ``k + 1``).
    """
    n = wf.n_tasks
    if np.isscalar(rep_counts):
        rep_counts = np.full(n, int(rep_counts))
    rep_counts = np.maximum(np.asarray(rep_counts, dtype=np.int64), 1)

    ranks = b_levels(wf, env)
    order = sorted(range(n), key=lambda t: -ranks[t])

    timeline = _VMTimeline(env.n_vms)
    placements: list[Placement] = []
    original: dict[int, Placement] = {}
    scheduled: set[int] = set()
    replicas_done: set[int] = set()

    def ready_time(task: int, vm: int) -> float:
        r = 0.0
        for par, d in wf.parents[task]:
            pp = original[par]
            r = max(r, pp.eft + env.transfer_time(d, pp.vm, vm))
        return r

    def place_replicas(task: int) -> None:
        """Replicas on distinct VMs with minimum *append* ESTs.

        Following [8] (replicas are scheduled after the children), replica
        slots go after everything already on the VM timeline: they are
        standby copies that at runtime execute only if still needed
        (CheckpointHEFT skips copies of completed tasks).
        """
        if task in replicas_done:
            return
        replicas_done.add(task)
        used_vms = {original[task].vm}
        # standby provisioning: a replica slot opens no earlier than the
        # original's estimated finish plus a speculative-grace margin
        # ("if one copy fails, one of its replicas is scheduled and
        # executed", Section 1) -- so replicas fire only for copies that
        # failed or are badly overdue, not in a race with healthy originals
        orig = original[task]
        floor = orig.eft + 0.5 * orig.duration
        for copy in range(1, int(rep_counts[task])):
            best = None  # (est, vm, dur)
            for vm in range(env.n_vms):
                if vm in used_vms and len(used_vms) < env.n_vms:
                    continue
                dur = float(env.time_on_vm[task, vm])
                est = timeline.append_slot(vm, max(ready_time(task, vm), floor))
                if best is None or est < best[0]:
                    best = (est, vm, dur)
            est, vm, dur = best
            used_vms.add(vm)
            timeline.insert(vm, est, est + dur)
            placements.append(Placement(task, copy, vm, est, est + dur))

    # -- pass 1: originals via min-EFT insertion (HEFT proper), identical to
    #    the plain-HEFT baseline so replication cannot degrade the primary
    #    assignment ---------------------------------------------------------
    for t in order:
        best = None  # (eft, est, vm)
        for vm in range(env.n_vms):
            dur = float(env.time_on_vm[t, vm])
            est = timeline.earliest_slot(vm, ready_time(t, vm), dur)
            eft = est + dur
            if best is None or eft < best[0]:
                best = (eft, est, vm)
        eft, est, vm = best
        timeline.insert(vm, est, eft)
        p = Placement(t, 0, vm, est, eft)
        placements.append(p)
        original[t] = p
        scheduled.add(t)

    # -- pass 2 (Algorithm 2 lines 7-9): replicas of t' are placed once all
    #    children of t' are scheduled -- trivially true after pass 1, so we
    #    emit them in rank order; each goes after the existing timeline. ----
    for t in order:
        place_replicas(t)

    return Schedule(wf, env, placements, ranks)
