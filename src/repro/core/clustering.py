"""Triplet-loss agglomerative clustering -> replication counts.

Implements Algorithm 1 (steps 11-19) with the affinity of Eq. (5) (average
linkage over point pairs) and the triplet merge loss of Eq. (6):

    loss(C_i, C_j) = D_ij + lambda/(R-1) * sum_{k in eta(C_i, R), k != j} (D_ij - D_ik)

i.e. merge the pair that is mutually close *and* clearly closer than C_i's
other R-1 nearest superclusters -- preventing collapse into one giant or many
singleton clusters (paper Fig. 2/3).

The O(N^2) pairwise point-distance matrix is the compute hot spot; it is
computed either by the pure-jnp reference or by the Pallas TPU kernel in
``repro.kernels.pairwise_affinity`` (``backend="pallas"``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "pairwise_distances",
    "ClusteringResult",
    "triplet_agglomerate",
    "replication_counts",
]


def pairwise_distances(points: np.ndarray, *, backend: str = "jnp") -> np.ndarray:
    """(N, N) Euclidean distance matrix between task embeddings."""
    if backend == "pallas":
        from repro.kernels.pairwise_affinity import ops as pa_ops

        return np.asarray(pa_ops.pairwise_distance(points, interpret=True))
    from repro.kernels.pairwise_affinity import ref as pa_ref

    return np.asarray(pa_ref.pairwise_distance(points))


@dataclasses.dataclass
class ClusteringResult:
    labels: np.ndarray                 # (N,) cluster index per point
    cluster_sizes: list[int]
    merge_history: list[tuple[int, int, float]]  # (a, b, distance at merge)
    min_intercluster_distance: float


def _cluster_loss_matrix(D: np.ndarray, R: int, lam: float) -> np.ndarray:
    """Ordered-pair triplet losses L[i, j] per Eq. (6)."""
    C = D.shape[0]
    big = np.inf
    Dm = D.copy()
    np.fill_diagonal(Dm, big)
    R_eff = min(R, C - 1)
    # eta(C_i, R): distances to the R nearest neighbours of each cluster
    neigh = np.sort(Dm, axis=1)[:, :R_eff]            # (C, R_eff)
    neigh_sum = neigh.sum(axis=1, keepdims=True)      # (C, 1)
    if R_eff <= 1:
        return Dm
    # For j in eta(i): sum over k != j of (D_ij - D_ik)
    #   = (R_eff - 1) * D_ij - (neigh_sum_i - D_ij)   when j is a neighbour.
    # For j outside eta(i) the merge is never selected anyway (some neighbour
    # has strictly smaller D); using the same formula keeps it vectorized.
    sum_term = (R_eff - 1) * Dm - (neigh_sum - Dm)
    L = Dm + lam / (R_eff - 1) * sum_term
    np.fill_diagonal(L, big)
    return L


def triplet_agglomerate(points: np.ndarray, *, n_clusters: int = 4,
                        R: int = 3, lam: float = 0.5,
                        dendro_threshold: float | None = None,
                        backend: str = "jnp") -> ClusteringResult:
    """Agglomerate N points down to ``n_clusters`` superclusters."""
    points = np.asarray(points, dtype=np.float64)
    N = points.shape[0]
    n_clusters = max(1, min(n_clusters, N))
    P = pairwise_distances(points, backend=backend)

    members: list[list[int]] = [[i] for i in range(N)]
    # pair-sum matrix S[a, b] = sum of point distances between clusters a, b
    S = P.astype(np.float64).copy()
    sizes = np.ones(N)
    alive = np.ones(N, dtype=bool)
    history: list[tuple[int, int, float]] = []

    def dist_matrix() -> np.ndarray:
        idx = np.where(alive)[0]
        sub = S[np.ix_(idx, idx)] / np.outer(sizes[idx], sizes[idx])
        return idx, sub

    while int(alive.sum()) > n_clusters:
        idx, D = dist_matrix()
        Dm = D.copy()
        np.fill_diagonal(Dm, np.inf)
        dmin = float(Dm.min())
        if dendro_threshold is not None and dmin > dendro_threshold:
            break  # dendrogram cut: branches now further apart than threshold
        L = _cluster_loss_matrix(D, R, lam)
        i, j = np.unravel_index(np.argmin(L), L.shape)
        a, b = int(idx[i]), int(idx[j])
        history.append((a, b, float(D[i, j])))
        # merge b into a
        members[a].extend(members[b])
        S[a, :] += S[b, :]
        S[:, a] += S[:, b]
        S[a, a] = 0.0
        sizes[a] += sizes[b]
        alive[b] = False

    idx, D = dist_matrix()
    Dm = D.copy()
    np.fill_diagonal(Dm, np.inf)
    labels = np.empty(N, dtype=np.int64)
    final_members = [members[a] for a in idx]
    for c, mem in enumerate(final_members):
        labels[mem] = c
    return ClusteringResult(
        labels=labels,
        cluster_sizes=[len(m) for m in final_members],
        merge_history=history,
        min_intercluster_distance=float(Dm.min()) if Dm.size > 1 else 0.0,
    )


def replication_counts(result: ClusteringResult, *,
                       rule_guard: bool = False,
                       priorities: np.ndarray | None = None,
                       exec_times: np.ndarray | None = None) -> np.ndarray:
    """Algorithm 1 steps 17-19: sort superclusters by size (descending);
    tasks in the i-th largest cluster get ``repCount = i`` total copies.

    The largest cluster (common, "ordinary" tasks) gets 1 copy (no replicas);
    the smallest (outliers: critical / long-running / high-priority tasks)
    gets the max count.  ``rule_guard`` applies the paper's rule-ensemble
    remark: a low-priority, short task that lands in an outlier cluster is
    capped at 2 copies.
    """
    order = np.argsort(-np.asarray(result.cluster_sizes), kind="stable")
    rank_of_cluster = np.empty(len(order), dtype=np.int64)
    rank_of_cluster[order] = np.arange(1, len(order) + 1)
    counts = rank_of_cluster[result.labels]
    if rule_guard and priorities is not None and exec_times is not None:
        pr = np.asarray(priorities)
        ex = np.asarray(exec_times)
        lowly = (pr <= np.median(pr)) & (ex <= np.median(ex))
        counts = np.where(lowly, np.minimum(counts, 2), counts)
    return counts.astype(np.int64)
