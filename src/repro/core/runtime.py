"""CheckpointHEFT discrete-event runtime (paper Algorithm 3).

Executes an over-provisioned HEFT :class:`~repro.core.heft.Schedule` against a
sampled :class:`~repro.core.failures.FailureTrace`:

* copies run FIFO per VM in scheduled-EST order ("backlog in HEFT order");
* a copy that cannot start because its VM has a backlog is terminated and
  counted as a failure unless it is the last hope for its task (steps 3-8);
* a VM failure mid-execution fails the copy (Case 1, steps 9-23); a VM that
  is down when the copy should start fails it (Case 2, steps 24-33);
* only when *all* ``repCount_t`` copies have failed is the task resubmitted
  (steps 14-15 / 25-26), either on the min-EST reliable VM (paying the
  re-execution of non-portable checkpointed work, steps 16-21) or on the same
  VM after recovery, resuming from the last checkpoint (steps 22-23);
* synchronized checkpoints every ``lam`` execution seconds cost ``gamma``
  each (Eq. 10); multi-level (SCR-style) configurations mark levels
  ``portable`` when restorable on a *different* VM (PFS backups).

The same engine powers the plain-HEFT and ReplicateAll(k) baselines through
:class:`SimConfig` switches (no resubmission / no skip-on-success).
"""
from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from .failures import FailureTrace
from .heft import Schedule

__all__ = ["CkptLevel", "SimConfig", "SimResult", "simulate"]


@dataclasses.dataclass(frozen=True)
class CkptLevel:
    lam: float              # checkpoint interval (execution seconds)
    gamma: float            # overhead per checkpoint (seconds)
    portable: bool = False  # restorable on a different VM (SCR PFS level)

    def __post_init__(self) -> None:
        if not self.lam > 0.0:
            raise ValueError(
                f"checkpoint interval lam must be > 0, got {self.lam!r}")
        if self.gamma < 0.0:
            raise ValueError(
                f"checkpoint overhead gamma must be >= 0, got {self.gamma!r}")


@dataclasses.dataclass
class SimConfig:
    ckpt_levels: tuple[CkptLevel, ...] = ()
    resubmit: bool = True            # Algorithm 3 resubmission on last failure
    skip_when_complete: bool = True  # don't start copies of finished tasks
    busy_terminate: bool = True      # steps 3-8 backlog termination
    backlog_tol: float = 120.0       # seconds of backlog tolerated (step 3)
    restore_cost: float = 0.0        # extra work to restore a portable ckpt
    max_resub_per_task: int = 8
    max_events: int = 2_000_000

    def overhead_rate(self) -> float:
        return sum(l.gamma / l.lam for l in self.ckpt_levels)

    def effective_duration(self, work: float) -> float:
        """work + checkpoint overheads, Eq. (10) amortized continuously."""
        return work * (1.0 + self.overhead_rate())

    def work_from_elapsed(self, elapsed: float) -> float:
        return elapsed / (1.0 + self.overhead_rate())

    def salvage(self, work_done: float, *, same_vm: bool) -> float:
        """alpha_t * lam: completed-checkpoint work reusable at restart."""
        best = 0.0
        for l in self.ckpt_levels:
            if same_vm or l.portable:
                best = max(best, math.floor(work_done / l.lam) * l.lam)
        return best


@dataclasses.dataclass
class _Copy:
    cid: int
    task: int
    vm: int
    sched_est: float
    work: float                 # remaining work (execution seconds)
    copy_idx: int = 0           # 0 = original, >=1 standby replica
    is_resubmission: bool = False
    status: str = "pending"
    ready: float = math.inf
    ast: float = math.nan
    aft: float = math.nan
    executed: float = 0.0


@dataclasses.dataclass
class SimResult:
    completed: bool
    tet: float
    usage: float            # processor seconds executed (incl. ckpt overhead)
    wastage: float          # beyond-last-checkpoint + late-replica seconds
    ckpt_overhead: float
    n_resubmissions: int
    n_failures: int
    n_terminated: int
    n_skipped: int
    task_complete: dict[int, float]
    events: int


def simulate(schedule: Schedule, trace: FailureTrace, cfg: SimConfig) -> SimResult:
    wf, env = schedule.workflow, schedule.env
    n_vms = env.n_vms
    failing = set(trace.failing_vms)
    reliable = [v for v in range(n_vms) if v not in failing]

    copies: list[_Copy] = []
    by_task: dict[int, list[int]] = {t: [] for t in range(wf.n_tasks)}
    for p in schedule.placements:
        c = _Copy(cid=len(copies), task=p.task, vm=p.vm, sched_est=p.est,
                  work=float(env.time_on_vm[p.task, p.vm]), copy_idx=p.copy)
        copies.append(c)
        by_task[p.task].append(c.cid)

    rep_count = {t: len(cids) for t, cids in by_task.items()}
    failures = {t: 0 for t in range(wf.n_tasks)}
    resub_count = {t: 0 for t in range(wf.n_tasks)}
    task_complete: dict[int, float] = {}
    complete_vm: dict[int, int] = {}

    vm_queue: dict[int, list[int]] = {v: [] for v in range(n_vms)}
    vm_busy_until = np.zeros(n_vms)
    running_on: dict[int, int | None] = {v: None for v in range(n_vms)}

    stats = {"usage": 0.0, "waste": 0.0, "ckpt": 0.0,
             "resub": 0, "fail": 0, "term": 0, "skip": 0}

    heap: list[tuple[float, int, str, int]] = []
    seq = [0]

    def push(time: float, kind: str, payload: int) -> None:
        heapq.heappush(heap, (time, seq[0], kind, payload))
        seq[0] += 1

    # ---- helpers ----------------------------------------------------------
    def parents_done(task: int) -> bool:
        return all(p in task_complete for p, _ in wf.parents[task])

    def ready_time(copy: _Copy) -> float:
        r = 0.0
        for par, d in wf.parents[copy.task]:
            r = max(r, task_complete[par] +
                    env.transfer_time(d, complete_vm[par], copy.vm))
        return r

    def alive_siblings(copy: _Copy) -> int:
        return sum(1 for cid in by_task[copy.task]
                   if cid != copy.cid and
                   copies[cid].status in ("pending", "queued", "running"))

    def min_est_reliable(now: float) -> tuple[float, int]:
        pool = reliable if reliable else list(range(n_vms))
        best_t, best_v = math.inf, pool[0]
        for v in pool:
            est = max(now, float(vm_busy_until[v]))
            if est < best_t:
                best_t, best_v = est, v
        return best_t, best_v

    def account(copy: _Copy, start_t: float, end_t: float) -> None:
        elapsed = max(0.0, end_t - start_t)
        copy.executed += elapsed
        stats["usage"] += elapsed
        rate = cfg.overhead_rate()
        stats["ckpt"] += elapsed * rate / (1.0 + rate)

    def enqueue(copy: _Copy, ready: float, *, front: bool = False) -> None:
        copy.status = "queued"
        if copy.copy_idx > 0 and not copy.is_resubmission:
            # standby replica: its HEFT slot (scheduled after the children,
            # [8]) is an earliest-start floor, so it runs only if the task
            # is still incomplete by then
            ready = max(ready, copy.sched_est)
        copy.ready = ready
        q = vm_queue[copy.vm]
        if front:
            q.insert(0, copy.cid)
        else:
            q.append(copy.cid)
            q.sort(key=lambda c: copies[c].sched_est)
        push(ready, "vm_try", copy.vm)
        if cfg.busy_terminate:
            push(ready + cfg.backlog_tol, "vm_try", copy.vm)

    def spawn_resubmission(task: int, vm: int, work: float,
                           ready: float) -> None:
        stats["resub"] += 1
        resub_count[task] += 1
        new = _Copy(cid=len(copies), task=task, vm=vm, sched_est=ready,
                    work=max(work, 1e-3), is_resubmission=True)
        copies.append(new)
        by_task[task].append(new.cid)
        enqueue(new, ready, front=True)

    # ---- resubmission, Case 1 (steps 16-23) --------------------------------
    def resubmit_case1(copy: _Copy, now: float, down_until: float,
                       work_done: float) -> None:
        salv_same = cfg.salvage(work_done, same_vm=True)
        salv_move = cfg.salvage(work_done, same_vm=False)
        min_est, v_new = min_est_reliable(now)
        overhead = max(0.0, salv_same - salv_move)       # step 19
        full_work = float(env.time_on_vm[copy.task, copy.vm])
        forced = resub_count[copy.task] >= cfg.max_resub_per_task
        if forced or (min_est + overhead < down_until):  # steps 20-21
            stats["waste"] += max(0.0, copy.executed - salv_move)
            frac = salv_move / max(full_work, 1e-9)
            w = float(env.time_on_vm[copy.task, v_new]) * (1.0 - frac)
            spawn_resubmission(copy.task, v_new, w + cfg.restore_cost, min_est)
        else:                                            # steps 22-23
            stats["waste"] += max(0.0, copy.executed - salv_same)
            spawn_resubmission(copy.task, copy.vm,
                               max(copy.work - salv_same, 1e-3), down_until)

    # ---- start execution (Case-1 outcome precomputed from the trace) -------
    def start(copy: _Copy, now: float) -> None:
        copy.status = "running"
        copy.ast = now
        end = now + cfg.effective_duration(copy.work)
        running_on[copy.vm] = copy.cid
        if copy.vm in failing:
            nxt = trace.next_down_after(copy.vm, now)
            if nxt is not None and nxt[0] < end:         # fails at X
                vm_busy_until[copy.vm] = nxt[0]
                push(nxt[0], "end_fail", copy.cid)
                return
        vm_busy_until[copy.vm] = end
        push(end, "end_ok", copy.cid)

    # ---- the per-VM scheduling attempt -------------------------------------
    def vm_try(v: int, now: float) -> None:
        q = vm_queue[v]
        if running_on[v] is not None or now < vm_busy_until[v]:
            # ---- backlog termination sweep (steps 3-8) ---------------------
            # lateness is measured against the *scheduled* start: waiting
            # for a planned queue slot is not backlog, missing it is
            if cfg.busy_terminate:
                for cid in list(q):
                    copy = copies[cid]
                    if (copy.status == "queued" and not copy.is_resubmission
                            and now - max(copy.ready, copy.sched_est)
                            > cfg.backlog_tol
                            and alive_siblings(copy) > 0):
                        copy.status = "terminated"       # step 7
                        failures[copy.task] += 1         # step 8
                        stats["term"] += 1
                        q.remove(cid)
            return
        down = trace.interval_covering(v, now)
        i = 0
        min_ready = math.inf
        while i < len(q):
            copy = copies[q[i]]
            if copy.status != "queued":
                q.pop(i)
                continue
            if cfg.skip_when_complete and copy.task in task_complete:
                copy.status = "skipped"
                stats["skip"] += 1
                q.pop(i)
                continue
            if copy.ready > now:
                # standby replicas with later floors must not block the
                # queue: keep scanning for a ready copy (work-conserving)
                min_ready = min(min_ready, copy.ready)
                i += 1
                continue
            if copy.copy_idx > 0 and not copy.is_resubmission:
                # standby activation: while a sibling copy is actually
                # running, defer to its expected completion -- the replica
                # fires only for failed / backlogged / overdue copies
                # ("if one copy fails, one of its replicas is scheduled
                # and executed", Section 1)
                defer = 0.0
                for cid2 in by_task[copy.task]:
                    o = copies[cid2]
                    if o.cid != copy.cid and o.status == "running":
                        defer = max(defer,
                                    o.ast + cfg.effective_duration(o.work))
                if defer > now:
                    copy.ready = defer + 1e-6
                    min_ready = min(min_ready, copy.ready)
                    i += 1
                    continue
            if down is not None:
                # ---- Case 2: VM currently down (steps 24-33) ---------------
                x, y = down
                q.pop(i)
                copy.status = "failed"                   # step 25
                failures[copy.task] += 1
                stats["fail"] += 1
                if (failures[copy.task] >= rep_count[copy.task]
                        and copy.task not in task_complete and cfg.resubmit):
                    min_est, v_new = min_est_reliable(now)
                    if min_est < y:                      # steps 30-31
                        spawn_resubmission(
                            copy.task, v_new,
                            float(env.time_on_vm[copy.task, v_new]), min_est)
                    else:                                # steps 32-33
                        spawn_resubmission(
                            copy.task, v,
                            float(env.time_on_vm[copy.task, v]), y)
                continue
            q.pop(i)
            start(copy, now)
            return
        if min_ready < math.inf:
            push(min_ready, "vm_try", v)

    # ---- task completion ----------------------------------------------------
    def complete(copy: _Copy, now: float) -> None:
        t = copy.task
        if t in task_complete:
            # a sibling already finished: late-replica waste (type 2)
            stats["waste"] += min(copy.executed,
                                  max(0.0, now - task_complete[t]))
            return
        task_complete[t] = now
        complete_vm[t] = copy.vm
        for child, _ in wf.children[t]:
            if parents_done(child):
                for cid in by_task[child]:
                    ch = copies[cid]
                    if ch.status == "pending":
                        enqueue(ch, ready_time(ch))

    # ---- seed entry tasks ----------------------------------------------------
    for t in wf.entry_tasks():
        for cid in by_task[t]:
            enqueue(copies[cid], 0.0)

    events = 0
    while heap and events < cfg.max_events:
        now, _, kind, payload = heapq.heappop(heap)
        events += 1
        if kind == "vm_try":
            vm_try(payload, now)
        elif kind == "end_ok":
            copy = copies[payload]
            account(copy, copy.ast, now)
            copy.status = "done"
            copy.aft = now
            running_on[copy.vm] = None
            complete(copy, now)
            push(now, "vm_try", copy.vm)
        elif kind == "end_fail":
            copy = copies[payload]
            account(copy, copy.ast, now)
            running_on[copy.vm] = None
            down = trace.interval_covering(copy.vm, now) or (now, now + 1.0)
            copy.status = "failed"                       # step 14
            failures[copy.task] += 1
            stats["fail"] += 1
            work_done = cfg.work_from_elapsed(copy.executed)
            if (failures[copy.task] >= rep_count[copy.task]
                    and copy.task not in task_complete):
                if cfg.resubmit:
                    resubmit_case1(copy, now, down[1], work_done)
                else:
                    stats["waste"] += copy.executed
            else:
                stats["waste"] += max(
                    0.0, copy.executed - cfg.salvage(work_done, same_vm=True))
            push(down[1], "vm_try", copy.vm)

    completed = len(task_complete) == wf.n_tasks
    tet = max(task_complete.values()) if task_complete else 0.0
    waste = stats["waste"]
    if not completed:
        # failed run: every executed second was futile (paper Section 4.2)
        waste = stats["usage"]
    return SimResult(
        completed=completed,
        tet=tet,
        usage=stats["usage"],
        wastage=waste,
        ckpt_overhead=stats["ckpt"],
        n_resubmissions=stats["resub"],
        n_failures=stats["fail"],
        n_terminated=stats["term"],
        n_skipped=stats["skip"],
        task_complete=task_complete,
        events=events,
    )
