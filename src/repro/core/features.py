"""Task feature embeddings (paper Section 3.1).

Each task becomes a point in a 10-dimensional space.  The paper lists five
example features (w_t, e(t), priority, #parents, #children) and states the
analysis uses ten dimensions; we add five structural/criticality features in
the same spirit (B-level, T-level, output volume, depth, #descendants).
"""
from __future__ import annotations

import numpy as np

from .workflow import CloudEnvironment, Workflow

__all__ = ["task_features", "FEATURE_NAMES", "b_levels", "t_levels"]

FEATURE_NAMES = (
    "avg_exec_time",        # Eq. (1)
    "max_parent_transfer",  # Eq. (2) maxed over parents
    "priority",
    "n_parents",
    "n_children",
    "b_level",
    "t_level",
    "output_data_mb",
    "depth",
    "n_descendants",
)


def b_levels(wf: Workflow, env: CloudEnvironment) -> np.ndarray:
    """Upward rank: w_t + max_child (e(t,child) + rank(child))."""
    w = np.array([env.avg_exec_time(t.tid) for t in wf.tasks])
    rank = np.zeros(wf.n_tasks)
    for u in reversed(wf.topo_order()):
        best = 0.0
        for v, d in wf.children[u]:
            best = max(best, env.avg_transfer_time(d) + rank[v])
        rank[u] = w[u] + best
    return rank


def t_levels(wf: Workflow, env: CloudEnvironment) -> np.ndarray:
    """Downward rank (length of longest path from an entry node to t)."""
    w = np.array([env.avg_exec_time(t.tid) for t in wf.tasks])
    lvl = np.zeros(wf.n_tasks)
    for u in wf.topo_order():
        best = 0.0
        for p, d in wf.parents[u]:
            best = max(best, lvl[p] + w[p] + env.avg_transfer_time(d))
        lvl[u] = best
    return lvl


def task_features(wf: Workflow, env: CloudEnvironment) -> np.ndarray:
    """(n_tasks, 10) float array, axis order = ``FEATURE_NAMES``."""
    n = wf.n_tasks
    feats = np.zeros((n, len(FEATURE_NAMES)))
    bl, tl = b_levels(wf, env), t_levels(wf, env)
    depth = wf.depth()
    desc = wf.descendant_counts()
    for t in wf.tasks:
        i = t.tid
        parents = wf.parents[i]
        children = wf.children[i]
        max_transfer = max((env.avg_transfer_time(d) for _, d in parents), default=0.0)
        out_mb = sum(d for _, d in children)
        feats[i] = (
            env.avg_exec_time(i),
            max_transfer,
            float(t.priority),
            float(len(parents)),
            float(len(children)),
            bl[i],
            tl[i],
            out_mb,
            float(depth[i]),
            float(desc[i]),
        )
    return feats
