"""Evaluation metrics (paper Section 4.2).

* TET — total execution time (makespan) of the workflow.
* Resource Usage — processor seconds spent executing task copies
  (reported as a fraction of TET, Fig. 8).
* Resource Wastage — beyond-last-checkpoint losses + late-replica
  executions; failed workflows waste everything they executed (Fig. 9).
* SLR — TET / B-level of the first task on the (replica-aware) critical
  path (Fig. 10).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .features import b_levels
from .heft import Schedule
from .runtime import SimResult

__all__ = ["RunMetrics", "metrics_from_result", "aggregate"]


@dataclasses.dataclass
class RunMetrics:
    completed: bool
    tet: float
    usage: float
    wastage: float
    usage_frac: float      # usage / TET (paper Fig. 8 normalization)
    wastage_frac: float
    slr: float
    ckpt_overhead: float
    n_resubmissions: int


def slr(schedule: Schedule, tet: float) -> float:
    """TET / B-level of the first task on the critical path."""
    cp = schedule.critical_path()
    bl = b_levels(schedule.workflow, schedule.env)
    denom = float(bl[cp[0]]) if cp else 1.0
    return float(tet / max(denom, 1e-9))


def metrics_from_result(schedule: Schedule, res: SimResult) -> RunMetrics:
    tet = res.tet if res.completed else max(res.tet, schedule.makespan)
    return RunMetrics(
        completed=res.completed,
        tet=tet,
        usage=res.usage,
        wastage=res.wastage,
        usage_frac=res.usage / max(tet, 1e-9),
        wastage_frac=res.wastage / max(tet, 1e-9),
        slr=slr(schedule, tet) if res.completed else float("nan"),
        ckpt_overhead=res.ckpt_overhead,
        n_resubmissions=res.n_resubmissions,
    )


def aggregate(runs: list[RunMetrics]) -> dict[str, float]:
    """Average metrics over repeated executions (paper: 10 runs per DAX)."""
    if not runs:
        # np.mean([]) raises a RuntimeWarning and yields nan; make the
        # empty aggregate explicit instead.
        keys = ("usage", "usage_frac", "wastage", "wastage_frac",
                "ckpt_overhead", "resubmissions", "tet", "slr")
        return {"n_runs": 0.0, "success_rate": 0.0,
                **{k: float("nan") for k in keys}}
    ok = [r for r in runs if r.completed]
    out = {
        "n_runs": float(len(runs)),
        "success_rate": len(ok) / max(len(runs), 1),
        "usage": float(np.mean([r.usage for r in runs])),
        "usage_frac": float(np.mean([r.usage_frac for r in runs])),
        "wastage": float(np.mean([r.wastage for r in runs])),
        "wastage_frac": float(np.mean([r.wastage_frac for r in runs])),
        "ckpt_overhead": float(np.mean([r.ckpt_overhead for r in runs])),
        "resubmissions": float(np.mean([r.n_resubmissions for r in runs])),
    }
    out["tet"] = float(np.mean([r.tet for r in ok])) if ok else float("nan")
    out["slr"] = float(np.mean([r.slr for r in ok])) if ok else float("nan")
    return out
