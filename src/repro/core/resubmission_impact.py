"""Resubmission Impact (RI) heuristic — Plankensteiner et al. [7].

The baseline the paper's clustering module replaces: for each task, build a
variant workflow in which that task's runtime is doubled (simulating one
resubmission), recompute the HEFT makespan, and normalize the makespan
deltas into scores; tasks with high impact (critical-path-ish) get high
replication counts.  This is the "combinatorial" approach the paper calls
slow: it costs one HEFT schedule per task (O(n) HEFTs ~ O(n^3 v)) versus
CRCH's single clustering pass -- reproduced as a baseline and timed in
tests/benchmarks.
"""
from __future__ import annotations

import numpy as np

from .heft import heft_schedule
from .workflow import CloudEnvironment, Workflow

__all__ = ["resubmission_impact_counts"]


def resubmission_impact_counts(wf: Workflow, env: CloudEnvironment, *,
                               max_rep: int = 4,
                               resub_factor: float = 2.0) -> np.ndarray:
    """Replication counts in [1, max_rep] from normalized RI scores."""
    base = heft_schedule(wf, env, 1).makespan
    impact = np.zeros(wf.n_tasks)
    saved = env.time_on_vm
    for t in range(wf.n_tasks):
        env.time_on_vm = saved.copy()
        env.time_on_vm[t] *= resub_factor
        impact[t] = heft_schedule(wf, env, 1).makespan - base
    env.time_on_vm = saved
    impact = np.maximum(impact, 0.0)
    hi = impact.max()
    if hi <= 1e-12:
        return np.ones(wf.n_tasks, dtype=np.int64)
    score = impact / hi                       # normalized RI in [0, 1]
    counts = 1 + np.floor(score * (max_rep - 1 + 1e-9)).astype(np.int64)
    return np.clip(counts, 1, max_rep)
