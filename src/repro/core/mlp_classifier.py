"""Supervised replication-count learning (paper Section 3.1.1, Eqs. 3-4).

The paper: "When substantial labeled training data is present, a
Multilayered Perceptron works reasonably well" -- a softmax classifier
P_j(t_i) = exp(F_i . W_j) / sum_k exp(F_i . W_k) trained with cross-entropy
(Eq. 4) and Adam.  Labels are scarce in practice (hence CRCH's unsupervised
clustering), but once a site has accumulated (task-features -> chosen
replication count) history, this learner *distills* the clustering policy
and amortizes it to O(1) per task.

Implemented in jnp (jit + Adam) with one hidden layer.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MLPConfig", "ReplicationMLP"]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    n_features: int
    n_classes: int           # max replication count
    hidden: int = 32
    lr: float = 1e-2
    epochs: int = 300
    seed: int = 0


def _init(cfg: MLPConfig):
    k1, k2 = jax.random.split(jax.random.key(cfg.seed))
    s1 = 1.0 / np.sqrt(cfg.n_features)
    s2 = 1.0 / np.sqrt(cfg.hidden)
    return {
        "w1": s1 * jax.random.normal(k1, (cfg.n_features, cfg.hidden)),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": s2 * jax.random.normal(k2, (cfg.hidden, cfg.n_classes)),
        "b2": jnp.zeros((cfg.n_classes,)),
    }


def _logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def _loss(params, x, y_onehot):
    """Eq. (4): mean cross-entropy of the softmax in Eq. (3)."""
    logp = jax.nn.log_softmax(_logits(params, x), axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


@functools.partial(jax.jit, static_argnames=("lr",))
def _adam_epoch(params, m, v, t, x, y, *, lr: float):
    g = jax.grad(_loss)(params, x, y)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = t + 1

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** t)
        vh = v2 / (1 - b2 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2

    out = jax.tree.map(upd, params, g, m, v)
    leaf = lambda n: jax.tree.map(lambda u: u[n], out,
                                  is_leaf=lambda u: isinstance(u, tuple))
    return leaf(0), leaf(1), leaf(2), t


class ReplicationMLP:
    """Fit on (features, counts) pairs; predict counts for new tasks."""

    def __init__(self, cfg: MLPConfig):
        self.cfg = cfg
        self.params = _init(cfg)
        self.mean = np.zeros(cfg.n_features)
        self.scale = np.ones(cfg.n_features)

    def fit(self, features: np.ndarray, counts: np.ndarray) -> float:
        x = np.asarray(features, np.float32)
        self.mean = x.mean(0)
        self.scale = np.where(x.std(0) < 1e-9, 1.0, x.std(0))
        x = jnp.asarray((x - self.mean) / self.scale)
        y = jax.nn.one_hot(jnp.asarray(counts, jnp.int32) - 1,
                           self.cfg.n_classes)
        m = jax.tree.map(jnp.zeros_like, self.params)
        v = jax.tree.map(jnp.zeros_like, self.params)
        t = jnp.zeros((), jnp.int32)
        params = self.params
        for _ in range(self.cfg.epochs):
            params, m, v, t = _adam_epoch(params, m, v, t, x, y,
                                          lr=self.cfg.lr)
        self.params = params
        return float(_loss(params, x, y))

    def predict(self, features: np.ndarray) -> np.ndarray:
        x = jnp.asarray((np.asarray(features, np.float32) - self.mean)
                        / self.scale)
        return np.asarray(jnp.argmax(_logits(self.params, x), -1) + 1)

    def accuracy(self, features: np.ndarray, counts: np.ndarray) -> float:
        return float(np.mean(self.predict(features) == np.asarray(counts)))
