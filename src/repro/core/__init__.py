"""CRCH core: the paper's contribution as a composable library.

Pipeline (paper Fig. 1):
  features -> PCA -> triplet clustering -> replication counts (Algorithm 1)
  -> over-provisioned HEFT (Algorithm 2) -> CheckpointHEFT runtime
  (Algorithm 3) with the Lemma-3.1 dynamic checkpoint interval.
"""
from .workflow import Task, Workflow, CloudEnvironment, generate_workflow, WORKFLOW_TYPES
from .failures import Environment, ENVIRONMENTS, FailureTrace, sample_failure_trace
from .features import task_features, FEATURE_NAMES, b_levels, t_levels
from .pca import PCAResult, fit_pca
from .clustering import pairwise_distances, triplet_agglomerate, replication_counts
from .heft import Placement, Schedule, heft_schedule
from .runtime import CkptLevel, SimConfig, SimResult, simulate
from .crch import CRCHConfig, CRCHPlan, plan, run, sim_config
from .metrics import RunMetrics, metrics_from_result, aggregate
from .mlp_classifier import MLPConfig, ReplicationMLP
from .resubmission_impact import resubmission_impact_counts
from .dax import load_dax, parse_dax
from . import baselines, checkpoint_policy

__all__ = [
    "Task", "Workflow", "CloudEnvironment", "generate_workflow", "WORKFLOW_TYPES",
    "Environment", "ENVIRONMENTS", "FailureTrace", "sample_failure_trace",
    "task_features", "FEATURE_NAMES", "b_levels", "t_levels",
    "PCAResult", "fit_pca",
    "pairwise_distances", "triplet_agglomerate", "replication_counts",
    "Placement", "Schedule", "heft_schedule",
    "CkptLevel", "SimConfig", "SimResult", "simulate",
    "CRCHConfig", "CRCHPlan", "plan", "run", "sim_config",
    "RunMetrics", "metrics_from_result", "aggregate",
    "MLPConfig", "ReplicationMLP", "resubmission_impact_counts",
    "load_dax", "parse_dax",
    "baselines", "checkpoint_policy",
]
