"""CRCH: Checkpointing and Replication based on Clustering Heuristics.

The end-to-end pipeline of paper Fig. 1: features -> PCA -> triplet
clustering -> replication counts (Algorithm 1) -> over-provisioned HEFT
(Algorithm 2) -> CheckpointHEFT runtime (Algorithm 3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import checkpoint_policy
from .clustering import ClusteringResult, replication_counts, triplet_agglomerate
from .failures import ENVIRONMENTS, FailureTrace
from .features import task_features
from .heft import Schedule, heft_schedule
from .pca import PCAResult, fit_pca
from .runtime import CkptLevel, SimConfig, SimResult, simulate
from .workflow import CloudEnvironment, Workflow

__all__ = ["CRCHConfig", "CRCHPlan", "plan", "run"]


@dataclasses.dataclass
class CRCHConfig:
    cov_threshold: float = 0.35      # PCA coverage-of-variance stop (Fig. 5 optimum)
    max_rep_count: int = 4           # number of superclusters K (Fig. 6)
    triplet_R: int = 3               # neighbourhood size in Eq. (6)
    triplet_lambda: float = 0.5      # triplet weight in Eq. (6)
    rule_guard: bool = False         # paper's rule-ensemble cap (off = faithful)
    ckpt_lambda: float | None = None  # None -> dynamic lambda* (Lemma 3.1)
    ckpt_gamma: float = 2.0          # per-checkpoint overhead (seconds)
    backend: str = "jnp"             # "jnp" | "pallas" distance matrix
    busy_terminate: bool = True
    backlog_tol: float = 120.0


@dataclasses.dataclass
class CRCHPlan:
    schedule: Schedule
    rep_counts: np.ndarray
    pca: PCAResult
    clustering: ClusteringResult
    ckpt_lambda: float


def plan(wf: Workflow, env: CloudEnvironment, cfg: CRCHConfig | None = None,
         *, environment: str = "normal") -> CRCHPlan:
    cfg = cfg or CRCHConfig()
    feats = task_features(wf, env)
    pca = fit_pca(feats, cfg.cov_threshold)
    clustering = triplet_agglomerate(
        pca.projected, n_clusters=cfg.max_rep_count,
        R=cfg.triplet_R, lam=cfg.triplet_lambda, backend=cfg.backend)
    counts = replication_counts(
        clustering, rule_guard=cfg.rule_guard,
        priorities=feats[:, 2], exec_times=feats[:, 0])
    schedule = heft_schedule(wf, env, counts)
    if cfg.ckpt_lambda is not None:
        lam = float(cfg.ckpt_lambda)
    else:
        # lambda* from the no-replica failure term: checkpoints exist for the
        # resubmission path, i.e. the event that all replicas already failed
        lam = checkpoint_policy.optimal_lambda(
            schedule, ENVIRONMENTS[environment], gamma=cfg.ckpt_gamma,
            rep_counts=None)
    return CRCHPlan(schedule=schedule, rep_counts=counts, pca=pca,
                    clustering=clustering, ckpt_lambda=lam)


def sim_config(plan_: CRCHPlan, cfg: CRCHConfig | None = None) -> SimConfig:
    cfg = cfg or CRCHConfig()
    return SimConfig(
        ckpt_levels=(CkptLevel(plan_.ckpt_lambda, cfg.ckpt_gamma,
                               portable=False),),
        resubmit=True,
        skip_when_complete=True,
        busy_terminate=cfg.busy_terminate,
        backlog_tol=cfg.backlog_tol,
    )


def run(wf: Workflow, env: CloudEnvironment, trace: FailureTrace,
        cfg: CRCHConfig | None = None, *,
        environment: str = "normal") -> tuple[SimResult, CRCHPlan]:
    cfg = cfg or CRCHConfig()
    plan_ = plan(wf, env, cfg, environment=environment)
    result = simulate(plan_.schedule, trace, sim_config(plan_, cfg))
    return result, plan_
