"""Workflow DAG representation + scientific-workflow generators.

Faithful to the paper's setting (Section 4.1): a workflow is read from a
DAX-like description as three matrices

  1. (Task x Task)  data to be transferred between dependent tasks
  2. (Task x VM)    runtime of a task on a given VM
  3. (VM x VM)      transmission rate between two VMs

We provide structural generators for the four workflows used in the paper
(Montage, CyberShake, LIGO/Inspiral, SIPHT) following the shape/runtime
characterization of Juve et al., "Characterizing and Profiling Scientific
Workflows" (the paper's [5]).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = [
    "Task",
    "Workflow",
    "CloudEnvironment",
    "generate_workflow",
    "WORKFLOW_TYPES",
]


@dataclasses.dataclass(frozen=True)
class Task:
    """One vertex of the workflow DAG."""

    tid: int
    name: str
    runtime: float  # reference runtime in seconds (on a unit-speed VM)
    priority: int = 0


class Workflow:
    """A DAG of :class:`Task` with data-volume annotated dependencies.

    ``deps`` holds ``(child, parent, data_mb)`` triples, matching the paper's
    ``dependenciesList = {(t, t', d) | t' is a parent of t sending d units}``.
    """

    def __init__(self, name: str, tasks: list[Task],
                 deps: Iterable[tuple[int, int, float]]):
        self.name = name
        self.tasks = list(tasks)
        self.deps: list[tuple[int, int, float]] = [
            (int(c), int(p), float(d)) for (c, p, d) in deps
        ]
        n = len(self.tasks)
        self.parents: dict[int, list[tuple[int, float]]] = {t.tid: [] for t in tasks}
        self.children: dict[int, list[tuple[int, float]]] = {t.tid: [] for t in tasks}
        for child, parent, d in self.deps:
            if not (0 <= child < n and 0 <= parent < n):
                raise ValueError(f"dep ({child},{parent}) out of range")
            if child == parent:
                raise ValueError("self dependency")
            self.parents[child].append((parent, d))
            self.children[parent].append((child, d))
        self._check_acyclic()

    # -- structure ---------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def entry_tasks(self) -> list[int]:
        return [t.tid for t in self.tasks if not self.parents[t.tid]]

    def exit_tasks(self) -> list[int]:
        return [t.tid for t in self.tasks if not self.children[t.tid]]

    def topo_order(self) -> list[int]:
        indeg = {t.tid: len(self.parents[t.tid]) for t in self.tasks}
        stack = sorted([t for t, d in indeg.items() if d == 0])
        order: list[int] = []
        while stack:
            u = stack.pop()
            order.append(u)
            for v, _ in self.children[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != self.n_tasks:
            raise ValueError("workflow graph has a cycle")
        return order

    def _check_acyclic(self) -> None:
        self.topo_order()

    def depth(self) -> dict[int, int]:
        """Longest #edges from any entry task."""
        d = {t: 0 for t in range(self.n_tasks)}
        for u in self.topo_order():
            for v, _ in self.children[u]:
                d[v] = max(d[v], d[u] + 1)
        return d

    def descendant_counts(self) -> dict[int, int]:
        """|descendants(t)| per task (reachability count, not path count)."""
        order = self.topo_order()
        reach: dict[int, set[int]] = {t: set() for t in range(self.n_tasks)}
        for u in reversed(order):
            s: set[int] = set()
            for v, _ in self.children[u]:
                s.add(v)
                s |= reach[v]
            reach[u] = s
        return {t: len(s) for t, s in reach.items()}


class CloudEnvironment:
    """The (Task x VM) runtime and (VM x VM) transfer-rate matrices.

    * ``time_on_vm[t, r]`` — seconds for task ``t`` on VM ``r`` (paper's
      ``timeOnVm``).  Built from per-VM speed factors plus mild per-pair noise
      (heterogeneous Condor pool).
    * ``transfer_rate[r, r']`` — MB/s on the dedicated two-way line between
      VMs; ``inf`` on the diagonal (no self-transfer cost).
    """

    def __init__(self, workflow: Workflow, n_vms: int = 20, *,
                 seed: int = 0, speed_spread: float = 0.5,
                 base_bandwidth_mbps: float = 40.0):
        rng = np.random.default_rng(seed)
        self.n_vms = int(n_vms)
        runtimes = np.array([t.runtime for t in workflow.tasks])
        # VM speed factors in [1-spread, 1+spread]; "good" VMs are fast for most tasks.
        self.vm_speed = 1.0 + speed_spread * (2.0 * rng.random(n_vms) - 1.0)
        noise = 1.0 + 0.1 * rng.standard_normal((workflow.n_tasks, n_vms))
        noise = np.clip(noise, 0.7, 1.3)
        self.time_on_vm = runtimes[:, None] / self.vm_speed[None, :] * noise
        self.time_on_vm = np.maximum(self.time_on_vm, 1e-3)
        rate = base_bandwidth_mbps * (0.5 + rng.random((n_vms, n_vms)))
        rate = 0.5 * (rate + rate.T)  # two-way dedicated line: symmetric
        np.fill_diagonal(rate, np.inf)
        self.transfer_rate = rate

    # -- paper Eq. (1) -----------------------------------------------------
    def avg_exec_time(self, t: int) -> float:
        return float(np.mean(self.time_on_vm[t]))

    # -- paper Eq. (2): mean over distinct VM pairs -------------------------
    def avg_transfer_time(self, data_mb: float) -> float:
        r = self.transfer_rate
        mask = ~np.eye(self.n_vms, dtype=bool)
        return float(np.mean(data_mb / r[mask]))

    def transfer_time(self, data_mb: float, r_src: int, r_dst: int) -> float:
        if r_src == r_dst:
            return 0.0
        return float(data_mb / self.transfer_rate[r_src, r_dst])


# ---------------------------------------------------------------------------
# Workflow generators (structure approximating the Pegasus DAX families)
# ---------------------------------------------------------------------------

def _runtime(rng: np.random.Generator, mean: float, cv: float = 0.4) -> float:
    """Gamma-distributed runtime (Chen & Deelman model the paper cites)."""
    shape = 1.0 / (cv * cv)
    return float(rng.gamma(shape, mean / shape))


def _montage(n: int, rng: np.random.Generator):
    """Montage: I/O bound, many small tasks, wide levels + reduce spine."""
    tasks: list[Task] = []
    deps: list[tuple[int, int, float]] = []

    def add(name: str, mean_rt: float, priority: int = 0) -> int:
        tid = len(tasks)
        tasks.append(Task(tid, name, _runtime(rng, mean_rt), priority))
        return tid

    # allocate level widths so total ~= n
    w = max(4, (n - 5) // 3)          # mProject / mBackground width
    nd = max(4, n - 5 - 2 * w)        # mDiffFit width (~edge overlaps)
    proj = [add("mProjectPP", 12.0) for _ in range(w)]
    diff = []
    for i in range(nd):
        t = add("mDiffFit", 8.0)
        a, b = proj[i % w], proj[(i + 1) % w]
        deps.append((t, a, 2.0 + rng.random()))
        if b != a:
            deps.append((t, b, 2.0 + rng.random()))
        diff.append(t)
    concat = add("mConcatFit", 25.0, priority=1)
    for t in diff:
        deps.append((concat, t, 0.5))
    bg_model = add("mBgModel", 40.0, priority=2)
    deps.append((bg_model, concat, 0.5))
    bgs = []
    for i in range(w):
        t = add("mBackground", 10.0)
        deps.append((t, proj[i], 2.0 + rng.random()))
        deps.append((t, bg_model, 0.3))
        bgs.append(t)
    imgtbl = add("mImgtbl", 15.0, priority=1)
    for t in bgs:
        deps.append((imgtbl, t, 3.0))
    madd = add("mAdd", 60.0, priority=3)
    deps.append((madd, imgtbl, 1.0))
    for t in bgs:
        deps.append((madd, t, 3.0 + rng.random()))
    shrink = add("mShrink", 12.0, priority=1)
    deps.append((shrink, madd, 8.0))
    jpeg = add("mJPEG", 5.0, priority=1)
    deps.append((jpeg, shrink, 2.0))
    return tasks, deps


def _cybershake(n: int, rng: np.random.Generator):
    """CyberShake: CPU/memory intensive; pairs of SGT extracts feeding many
    seismogram syntheses, then peak-value + zip reduces."""
    tasks: list[Task] = []
    deps: list[tuple[int, int, float]] = []

    def add(name: str, mean_rt: float, priority: int = 0) -> int:
        tid = len(tasks)
        tasks.append(Task(tid, name, _runtime(rng, mean_rt), priority))
        return tid

    n_pairs = max(2, n // 20)
    per_pair = max(2, (n - 2 * n_pairs - 2) // (2 * n_pairs))
    sgt = [add("ExtractSGT", 110.0, priority=2) for _ in range(2 * n_pairs)]
    peaks = []
    for p in range(n_pairs):
        for _ in range(per_pair):
            syn = add("SeismogramSynthesis", 48.0)
            deps.append((syn, sgt[2 * p], 30.0 + 5 * rng.random()))
            deps.append((syn, sgt[2 * p + 1], 30.0 + 5 * rng.random()))
            pk = add("PeakValCalcOkaya", 2.0)
            deps.append((pk, syn, 0.5))
            peaks.append((syn, pk))
    zip_seis = add("ZipSeis", 20.0, priority=1)
    zip_psa = add("ZipPSA", 20.0, priority=1)
    for syn, pk in peaks:
        deps.append((zip_seis, syn, 1.0))
        deps.append((zip_psa, pk, 0.2))
    return tasks, deps


def _ligo(n: int, rng: np.random.Generator):
    """LIGO Inspiral: heavily CPU bound; TmpltBank->Inspiral->Thinca pipeline
    repeated twice with group fan-ins."""
    tasks: list[Task] = []
    deps: list[tuple[int, int, float]] = []

    def add(name: str, mean_rt: float, priority: int = 0) -> int:
        tid = len(tasks)
        tasks.append(Task(tid, name, _runtime(rng, mean_rt), priority))
        return tid

    group = 5
    n_groups = max(2, n // (2 * group + 2 + group + 1))
    tb_all, groups1 = [], []
    for _ in range(n_groups):
        tbs = [add("TmpltBank", 180.0, priority=1) for _ in range(group)]
        ins = []
        for tb in tbs:
            i = add("Inspiral", 460.0, priority=2)
            deps.append((i, tb, 1.0))
            ins.append(i)
        th = add("Thinca", 6.0)
        for i in ins:
            deps.append((th, i, 0.8))
        tb_all.extend(tbs)
        groups1.append(th)
    finals = []
    for th in groups1:
        trig = add("TrigBank", 6.0)
        deps.append((trig, th, 0.5))
        ins2 = []
        for _ in range(group):
            i2 = add("Inspiral2", 420.0, priority=2)
            deps.append((i2, trig, 1.0))
            ins2.append(i2)
        th2 = add("Thinca2", 6.0, priority=1)
        for i2 in ins2:
            deps.append((th2, i2, 0.8))
        finals.append(th2)
    sink = add("Sire", 10.0, priority=3)
    for th2 in finals:
        deps.append((sink, th2, 0.5))
    return tasks, deps


def _sipht(n: int, rng: np.random.Generator):
    """SIPHT: bioinformatics; wide Patser fan-in + heterogeneous mid-stage."""
    tasks: list[Task] = []
    deps: list[tuple[int, int, float]] = []

    def add(name: str, mean_rt: float, priority: int = 0) -> int:
        tid = len(tasks)
        tasks.append(Task(tid, name, _runtime(rng, mean_rt), priority))
        return tid

    n_pats = max(4, n - 12)
    pats = [add("Patser", 1.5) for _ in range(n_pats)]
    pc = add("PatserConcat", 3.0, priority=1)
    for p in pats:
        deps.append((pc, p, 0.1))
    transterm = add("Transterm", 35.0, priority=1)
    findterm = add("FindTerm", 90.0, priority=2)
    rnamotif = add("RNAMotif", 28.0, priority=1)
    blast = add("Blast", 210.0, priority=2)
    srna = add("SRNA", 20.0, priority=2)
    for t in (transterm, findterm, rnamotif, blast):
        deps.append((srna, t, 2.0))
    deps.append((srna, pc, 0.5))
    ffn = add("FFN_Blast", 120.0, priority=1)
    deps.append((ffn, srna, 4.0))
    paralog = add("BlastParalogues", 60.0)
    deps.append((paralog, srna, 4.0))
    synteny = add("BlastSynteny", 60.0)
    deps.append((synteny, srna, 4.0))
    candidate = add("BlastCandidate", 45.0)
    deps.append((candidate, srna, 4.0))
    annotate = add("SRNAAnnotate", 12.0, priority=3)
    for t in (ffn, paralog, synteny, candidate):
        deps.append((annotate, t, 1.0))
    return tasks, deps


_GENERATORS = {
    "montage": _montage,
    "cybershake": _cybershake,
    "ligo": _ligo,
    "inspiral": _ligo,  # alias used by the paper
    "sipht": _sipht,
}

WORKFLOW_TYPES = ("montage", "cybershake", "ligo", "sipht")


# per-family time scales: makespans land in the paper's regime (tens of
# minutes on 20 VMs) while preserving the CPU-intensity ordering
# LIGO >> CyberShake > SIPHT > Montage of Juve et al. [5]
_RUNTIME_SCALE = {"montage": 15.0, "cybershake": 5.0, "ligo": 2.5,
                  "inspiral": 2.5, "sipht": 8.0}


def generate_workflow(kind: str, n_tasks: int = 100, *, seed: int = 0,
                      runtime_scale: float | None = None) -> Workflow:
    """Generate a workflow of approximately ``n_tasks`` tasks.

    ``runtime_scale`` overrides the per-family default time scale; absolute
    scales are chosen so the Weibull MTBF / log-normal MTTR distributions of
    Section 4.1 are meaningful against the makespan.
    """
    kind = kind.lower()
    if kind not in _GENERATORS:
        raise ValueError(f"unknown workflow type {kind!r}; pick from {WORKFLOW_TYPES}")
    rng = np.random.default_rng(seed)
    scale = _RUNTIME_SCALE[kind] if runtime_scale is None else runtime_scale
    tasks, deps = _GENERATORS[kind](int(n_tasks), rng)
    tasks = [Task(t.tid, t.name, t.runtime * scale, t.priority)
             for t in tasks]
    return Workflow(f"{kind}-{len(tasks)}", tasks, deps)
