"""Pegasus DAX (XML) workflow parser.

The paper reads its workflows "as input in the form of a DAX file".  Our
benchmarks use structural generators (no network access), but real DAX
files from the Pegasus workflow gallery load directly::

    wf = load_dax("Montage_100.xml")

Supports the DAX 2/3 schema subset the simulators use: <job> runtime
attribute, <uses> file sizes for transfer volumes, <child>/<parent> edges.
"""
from __future__ import annotations

import xml.etree.ElementTree as ET

from .workflow import Task, Workflow

__all__ = ["load_dax", "parse_dax"]


def _strip(tag: str) -> str:
    return tag.rsplit("}", 1)[-1]


def parse_dax(xml_text: str, *, default_runtime: float = 10.0,
              name: str = "dax") -> Workflow:
    root = ET.fromstring(xml_text)
    tasks: list[Task] = []
    tid_by_id: dict[str, int] = {}
    out_files: dict[str, tuple[int, float]] = {}   # file -> (producer, MB)
    in_files: dict[int, list[tuple[str, float]]] = {}

    for el in root:
        if _strip(el.tag) != "job":
            continue
        jid = el.attrib["id"]
        runtime = float(el.attrib.get("runtime",
                                      el.attrib.get("run", default_runtime)))
        tid = len(tasks)
        tasks.append(Task(tid, el.attrib.get("name", jid), max(runtime, 1e-3)))
        tid_by_id[jid] = tid
        in_files[tid] = []
        for u in el:
            if _strip(u.tag) != "uses":
                continue
            fname = u.attrib.get("file", u.attrib.get("name", ""))
            size_mb = float(u.attrib.get("size", 0)) / 1e6
            link = u.attrib.get("link", "")
            if link == "output":
                out_files[fname] = (tid, size_mb)
            elif link == "input":
                in_files[tid].append((fname, size_mb))

    deps: dict[tuple[int, int], float] = {}
    # explicit control edges
    for el in root:
        if _strip(el.tag) != "child":
            continue
        child = tid_by_id.get(el.attrib["ref"])
        if child is None:
            continue
        for p in el:
            if _strip(p.tag) != "parent":
                continue
            parent = tid_by_id.get(p.attrib["ref"])
            if parent is None or parent == child:
                continue
            deps.setdefault((child, parent), 0.0)
    # data-flow volumes from file producers
    for child, files in in_files.items():
        for fname, size_mb in files:
            prod = out_files.get(fname)
            if prod is None or prod[0] == child:
                continue
            key = (child, prod[0])
            deps[key] = deps.get(key, 0.0) + max(size_mb, 1e-6)

    dep_list = [(c, p, max(d, 1e-6)) for (c, p), d in deps.items()]
    return Workflow(name, tasks, dep_list)


def load_dax(path: str, **kw) -> Workflow:
    with open(path) as f:
        return parse_dax(f.read(), name=path.rsplit("/", 1)[-1], **kw)
