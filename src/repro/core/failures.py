"""Resource-failure models (paper Section 4.1).

Three environments — *stable*, *normal*, *unstable* — each defined by

  * MTBF  ~ Weibull, shape in [11.5, 12.5]   (paper cites [7])
  * failure size (#VMs affected) ~ Weibull, shape in [1.5, 2.4]
  * MTTR  ~ log-normal, mean minutes ~ 6 / 3 / 1 for unstable/normal/stable
  * failing-VM set ~ uniform draw; at least ``n_reliable`` VMs never fail.

``FailureTrace.downtime[v]`` is the paper's ``L_v``: sorted disjoint
``(X, Y)`` intervals during which VM ``v`` is unavailable.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Environment", "ENVIRONMENTS", "FailureTrace", "sample_failure_trace"]


@dataclasses.dataclass(frozen=True)
class Environment:
    name: str
    mtbf_shape: float          # Weibull shape k for time-between-failures
    mtbf_scale_s: float        # Weibull scale (seconds)
    size_shape: float          # Weibull shape for failure size (#VMs)
    size_scale: float          # Weibull scale for failure size
    mttr_mean_s: float         # log-normal mean repair time (seconds)
    mttr_sigma: float          # log-normal sigma (of the underlying normal)

    def mttr_mu(self) -> float:
        # mean = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2
        return float(np.log(self.mttr_mean_s) - 0.5 * self.mttr_sigma**2)


# MTBF scales chosen so that, against makespans of tens of minutes on 20 VMs,
# failures are rare/occasional/frequent (paper: MTBF decreases from stable to
# unstable; MTTR ~ 6/3/1 minutes for unstable/normal/stable).
ENVIRONMENTS: dict[str, Environment] = {
    "stable": Environment("stable", mtbf_shape=12.5, mtbf_scale_s=28800.0,
                          size_shape=1.5, size_scale=1.0,
                          mttr_mean_s=60.0, mttr_sigma=0.35),
    "normal": Environment("normal", mtbf_shape=12.0, mtbf_scale_s=3600.0,
                          size_shape=2.0, size_scale=1.6,
                          mttr_mean_s=180.0, mttr_sigma=0.45),
    "unstable": Environment("unstable", mtbf_shape=11.5, mtbf_scale_s=1200.0,
                            size_shape=2.4, size_scale=2.4,
                            mttr_mean_s=360.0, mttr_sigma=0.55),
}


@dataclasses.dataclass
class FailureTrace:
    """Sampled failure realization for one simulation run."""

    env: Environment
    n_vms: int
    failing_vms: list[int]                      # the paper's FVM
    downtime: dict[int, list[tuple[float, float]]]  # the paper's L_v

    def reliable_vms(self) -> list[int]:
        fv = set(self.failing_vms)
        return [v for v in range(self.n_vms) if v not in fv]

    def is_down(self, vm: int, t: float) -> bool:
        return any(x <= t < y for (x, y) in self.downtime.get(vm, ()))

    def next_down_after(self, vm: int, t: float):
        """Earliest interval (X, Y) with X >= t (argmin of Alg. 3 step 11)."""
        for (x, y) in self.downtime.get(vm, ()):
            if x >= t:
                return (x, y)
        return None

    def interval_covering(self, vm: int, t: float):
        """Interval (X, Y) with X <= t < Y, if the VM is down at ``t``."""
        for (x, y) in self.downtime.get(vm, ()):
            if x <= t < y:
                return (x, y)
        return None

    def up_at_or_after(self, vm: int, t: float) -> float:
        """Earliest time >= t at which ``vm`` is up."""
        cur = t
        for (x, y) in self.downtime.get(vm, ()):
            if y <= cur:
                continue
            if x <= cur < y:
                cur = y
            elif x > cur:
                break
        return cur


def sample_failure_trace(env: Environment | str, n_vms: int, horizon_s: float, *,
                         n_reliable: int = 4, seed: int = 0) -> FailureTrace:
    """Draw FVM, MTBF/MTTR realizations per the paper's distributions.

    Failure *events* strike a random subset of the failing VMs; the event size
    is Weibull-distributed (paper 4.1), the affected VMs uniform over FVM.
    """
    if isinstance(env, str):
        env = ENVIRONMENTS[env]
    rng = np.random.default_rng(seed)

    # --- failing-VM set (uniform draw, keep >= n_reliable reliable) --------
    max_failing = max(0, n_vms - n_reliable)
    n_failing = min(max_failing, max(1, int(round(rng.uniform(0.3, 0.8) * max_failing))))
    failing = sorted(rng.choice(n_vms, size=n_failing, replace=False).tolist())

    downtime: dict[int, list[tuple[float, float]]] = {v: [] for v in failing}
    if failing:
        # stationary renewal process: randomize the phase of the first event
        # so short workflows still observe the long-run failure *rate*
        first_gap = env.mtbf_scale_s * rng.weibull(env.mtbf_shape)
        t = -rng.uniform(0.0, first_gap)
        first = True
        while t < horizon_s:
            gap = first_gap if first else env.mtbf_scale_s * rng.weibull(env.mtbf_shape)
            first = False
            t += max(gap, 1.0)
            if t >= horizon_s or t < 0.0:
                continue
            size = int(np.ceil(env.size_scale * rng.weibull(env.size_shape)))
            size = int(np.clip(size, 1, len(failing)))
            struck = rng.choice(failing, size=size, replace=False)
            mttr = rng.lognormal(env.mttr_mu(), env.mttr_sigma, size=size)
            for v, r in zip(struck, mttr):
                downtime[int(v)].append((t, t + float(max(r, 1.0))))

    # merge overlapping intervals per VM
    for v, ivs in downtime.items():
        ivs.sort()
        merged: list[tuple[float, float]] = []
        for x, y in ivs:
            if merged and x <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], y))
            else:
                merged.append((x, y))
        downtime[v] = merged

    return FailureTrace(env=env, n_vms=n_vms, failing_vms=failing, downtime=downtime)
