"""Dynamic checkpoint interval (paper Section 3.2, Lemma 3.1).

``TET_CRCH(lambda) = TET_{/CO}(lambda) * (1 + gamma/lambda)``  (Eq. 25)

with ``TET_{/CO}`` summed over the critical path (Eq. 24):

  TET_Ci = TET_Hi + WT_i + P_ti^{R_i} * [ P_same * (E_minEST_same + E[PF mod lam])
                                        + (1-P_same) * (E_minEST_diff + TET_Hi) ]

We estimate the model's sufficient statistics from the schedule and the
environment's distributions:

* ``P_ti`` — P(overlap) * |FVM|/|V| (Eqs. 15-17) with
  P(overlap) = 1 - exp(-duration / MTBF).
* ``E[PF mod lam] = lam / 2`` (uniform point-of-failure within an interval).
* ``P_same(lam)`` decreases in lam (paper's argument): moving is preferred
  exactly when the re-execution overhead ``alpha*lam ~ PF - PF mod lam`` stays
  below the remaining repair time; we use
  ``P_same = exp(-(E_minEST_diff + lam/2) / MTTR)``.
* ``Term2 = 1 + gamma/lam`` — checkpoint overhead (Eq. 10).

The optimum is found by golden-section search; an empirical grid tuner
(running the full simulator) backs Fig. 7b.
"""
from __future__ import annotations

import math

import numpy as np

from .failures import Environment
from .heft import Schedule

__all__ = ["model_tet", "optimal_lambda", "empirical_lambda_grid"]


def _cp_stats(schedule: Schedule):
    cp = schedule.critical_path()
    durations = [schedule.original(t).duration for t in cp]
    return cp, durations


def model_tet(lam: float, schedule: Schedule, env_model: Environment, *,
              gamma: float, rep_counts=None,
              e_min_est_diff: float | None = None) -> float:
    """Eq. 24-25 estimate of E[TET] for a given checkpoint interval."""
    lam = max(float(lam), 1e-3)
    cp, durs = _cp_stats(schedule)
    n_vms = schedule.env.n_vms
    # |FVM|/|V|: expectation of the uniform draw in failures.sample (~0.55
    # of the non-reliable pool)
    p_vm = 0.55 * max(n_vms - 4, 0) / n_vms
    mtbf = env_model.mtbf_scale_s * math.gamma(1.0 + 1.0 / env_model.mtbf_shape)
    mttr = env_model.mttr_mean_s
    if e_min_est_diff is None:
        # expected queue delay on the min-EST reliable VM ~ half a mean task
        e_min_est_diff = 0.5 * float(np.mean(durs))
    e_min_est_same = 0.5 * mttr

    total = 0.0
    for t, dur in zip(cp, durs):
        r_i = int(rep_counts[t]) if rep_counts is not None else 1
        p_overlap = 1.0 - math.exp(-dur / max(mtbf, 1e-9))
        p_t = p_overlap * p_vm                      # Eq. 17
        p_all_fail = p_t ** max(r_i, 1)             # Eq. 18
        p_same = math.exp(-(e_min_est_diff + 0.5 * lam) / max(mttr, 1e-9))
        ro = p_all_fail * (
            p_same * (e_min_est_same + 0.5 * lam)            # Eq. 20
            + (1.0 - p_same) * (e_min_est_diff + dur)        # Eq. 21
        )
        wt = 0.05 * dur                              # WT_i ~ N_w mean (Assn. 1)
        total += dur + wt + ro                       # Eq. 8
    return total * (1.0 + gamma / lam)               # Eq. 25


def optimal_lambda(schedule: Schedule, env_model: Environment, *,
                   gamma: float, rep_counts=None,
                   lo: float = 5.0, hi: float = 600.0) -> float:
    """Golden-section search for argmin_lambda of the Lemma 3.1 model."""
    phi = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = math.log(lo), math.log(hi)

    def f(x: float) -> float:
        return model_tet(math.exp(x), schedule, env_model, gamma=gamma,
                         rep_counts=rep_counts)

    c, d = b - phi * (b - a), a + phi * (b - a)
    fc, fd = f(c), f(d)
    for _ in range(40):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - phi * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + phi * (b - a)
            fd = f(d)
    return float(math.exp(0.5 * (a + b)))


def empirical_lambda_grid(schedule: Schedule, traces, lam_grid, *,
                          gamma: float):
    """Average simulated TET per lambda (used for Fig. 7b)."""
    from .runtime import CkptLevel, SimConfig, simulate

    rows = []
    for lam in lam_grid:
        cfg = SimConfig(ckpt_levels=(CkptLevel(float(lam), gamma),),
                        resubmit=True, skip_when_complete=True,
                        busy_terminate=False)
        tets = []
        for tr in traces:
            res = simulate(schedule, tr, cfg)
            if res.completed:
                tets.append(res.tet)
        rows.append((float(lam),
                     float(np.mean(tets)) if tets else float("nan")))
    return rows
