"""PCA with a coverage-of-variance stopping rule (Algorithm 1, steps 2-10).

The paper whitens (mean-subtract + standardize) the task features, then adds
principal components one at a time until the cumulative explained variance
exceeds a threshold (their optimum: COV in [0.3, 0.4], Fig. 5).

Implemented with jnp so it runs on-accelerator alongside the clustering
kernel; inputs are small (<= ~1e3 x 10) so this also JITs trivially.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PCAResult", "fit_pca", "project"]


@dataclasses.dataclass
class PCAResult:
    mean: np.ndarray            # (F,)
    scale: np.ndarray           # (F,)
    components: np.ndarray      # (K, F) orthonormal rows
    explained_ratio: np.ndarray  # (K,)
    cov: float                  # cumulative coverage of variance actually reached
    projected: np.ndarray       # (N, K)


@functools.partial(jax.jit, static_argnames=())
def _svd_whitened(x: jnp.ndarray):
    mean = jnp.mean(x, axis=0)
    std = jnp.std(x, axis=0)
    std = jnp.where(std < 1e-12, 1.0, std)
    xw = (x - mean) / std
    # economy SVD of the whitened data: principal axes = rows of vt
    u, s, vt = jnp.linalg.svd(xw, full_matrices=False)
    var = (s * s) / jnp.maximum(x.shape[0] - 1, 1)
    ratio = var / jnp.maximum(jnp.sum(var), 1e-12)
    return mean, std, vt, ratio, xw


def fit_pca(features: np.ndarray, threshold: float = 0.35) -> PCAResult:
    """Fit PCA keeping the fewest components with sum(ratio) >= threshold."""
    x = jnp.asarray(np.asarray(features, dtype=np.float64), dtype=jnp.float32)
    mean, std, vt, ratio, xw = _svd_whitened(x)
    ratio_np = np.asarray(ratio)
    cum = np.cumsum(ratio_np)
    k = int(np.searchsorted(cum, threshold) + 1)
    k = min(max(k, 1), ratio_np.shape[0])
    comps = np.asarray(vt)[:k]
    proj = np.asarray(xw @ jnp.asarray(comps).T)
    return PCAResult(
        mean=np.asarray(mean),
        scale=np.asarray(std),
        components=comps,
        explained_ratio=ratio_np[:k],
        cov=float(cum[k - 1]),
        projected=proj,
    )


def project(res: PCAResult, features: np.ndarray) -> np.ndarray:
    xw = (np.asarray(features) - res.mean) / res.scale
    return xw @ res.components.T
