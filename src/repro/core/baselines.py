"""Baselines the paper compares against: plain HEFT, ReplicateAll(k), SCR.

* HEFT [13]: no replicas, no checkpointing, no resubmission -> any VM failure
  that hits a task kills the workflow.
* ReplicateAll(k) [11]: every task gets k extra replicas (paper uses k=3, so
  4 executions per task), no resubmission, no checkpointing, no dynamic
  skip-on-success -- replicas always execute (paper Section 4.2).
* SCR [17]: multi-level checkpoint/restart -- frequent cheap local
  checkpoints (non-portable) + infrequent expensive Parallel-File-System
  backups (portable).  Compared against CRCH's light-weight single-level
  pointer checkpoints in Fig. 7a (both with no replicas).
"""
from __future__ import annotations

import numpy as np

from .failures import FailureTrace
from .heft import Schedule, heft_schedule
from .runtime import CkptLevel, SimConfig, SimResult, simulate
from .workflow import CloudEnvironment, Workflow

__all__ = [
    "heft_plan", "heft_sim_config",
    "replicate_all_plan", "replicate_all_sim_config",
    "scr_sim_config", "crch_ckpt_only_sim_config",
]


def heft_plan(wf: Workflow, env: CloudEnvironment) -> Schedule:
    return heft_schedule(wf, env, 1)


def heft_sim_config() -> SimConfig:
    return SimConfig(ckpt_levels=(), resubmit=False, skip_when_complete=True,
                     busy_terminate=False)


def replicate_all_plan(wf: Workflow, env: CloudEnvironment,
                       k: int = 3) -> Schedule:
    return heft_schedule(wf, env, k + 1)


def replicate_all_sim_config() -> SimConfig:
    # the static schedule is executed as-is: every copy runs (no dynamic
    # skip, no resubmission, no checkpointing); wastage = replica seconds
    # executed after the first copy succeeded (paper Section 4.2)
    return SimConfig(ckpt_levels=(), resubmit=False, skip_when_complete=False,
                     busy_terminate=False)


def scr_sim_config(*, local_lambda: float = 30.0, local_gamma: float = 1.5,
                   pfs_lambda: float = 300.0, pfs_gamma: float = 20.0,
                   restore_cost: float = 15.0) -> SimConfig:
    """SCR-style two-level checkpointing, no replicas (Fig. 7a setting)."""
    return SimConfig(
        ckpt_levels=(CkptLevel(local_lambda, local_gamma, portable=False),
                     CkptLevel(pfs_lambda, pfs_gamma, portable=True)),
        resubmit=True, skip_when_complete=True, busy_terminate=False,
        restore_cost=restore_cost,
    )


def crch_ckpt_only_sim_config(*, lam: float = 30.0,
                              gamma: float = 1.5) -> SimConfig:
    """CRCH checkpointing with no replicas (Fig. 7 setting): light-weight
    local checkpoints whose *data* pointers live in global memory, so the
    restore itself is cheap, but program state is not portable."""
    return SimConfig(
        ckpt_levels=(CkptLevel(lam, gamma, portable=False),),
        resubmit=True, skip_when_complete=True, busy_terminate=False,
    )
