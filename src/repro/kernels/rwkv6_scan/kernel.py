"""Pallas TPU kernel: chunked WKV6 linear recurrence.

TPU adaptation of the Finch recurrence: instead of one-token-at-a-time
(serial, VPU-bound), each grid step processes a T=16 token chunk of one
(batch, head) pair entirely on the MXU:

    o_intra[t]  = (r_t * P_{t-1}) @ S            (chunk-entry state)
    A[t, j]     = (r_t * P_{t-1}) . (k_j / P_j)  for j < t   (tril matmul)
    o[t]       += A @ v + (r_t . u*k_t) v_t      (bonus diagonal)
    S'          = diag(P_T) S + (k * P_T / P_j)^T @ v

with P the in-chunk cumulative decay.  T=16 bounds the exp() arguments
(|log w| clamped at 2.5 in the model) so everything stays in fp32 range.
The (N, N) state lives in VMEM scratch across chunk steps; N=64 keeps the
whole working set (~100 KiB) resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)           # (T, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = w_ref[0, 0].astype(jnp.float32)          # log decay
    u = u_ref[0].astype(jnp.float32)              # (1, N) -> broadcast

    cum = jnp.cumsum(lw, axis=0)                  # (T, N) inclusive
    p_prev = jnp.exp(cum - lw)
    p_inv = jnp.exp(-cum)
    p_end = jnp.exp(cum[-1:])                     # (1, N)

    S = s_ref[...]                                # (N, N)
    rq = r * p_prev                               # decayed queries
    o_inter = jax.lax.dot_general(rq, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    A = jax.lax.dot_general(rq, k * p_inv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (T, T)
    ti = jax.lax.broadcasted_iota(jnp.int32, A.shape, 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, A.shape, 1)
    A = jnp.where(ti > tj, A, 0.0)
    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)          # (T, 1)
    o = o_inter + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32) \
        + bonus * v
    o_ref[0, 0] = o.astype(o_ref.dtype)
    kd = k * (p_end * p_inv)                       # (T, N)
    S_new = p_end.T * S + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, log_w, u, *, chunk: int = 16,
                interpret: bool = False):
    """r,k,v,log_w: (B, H, T, N) with T % chunk == 0; u: (H, N)."""
    b, h, t, n = r.shape
    assert t % chunk == 0
    grid = (b, h, t // chunk)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, n), lambda b_, h_, ic: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, n),
                               lambda b_, h_, ic: (b_, h_, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
