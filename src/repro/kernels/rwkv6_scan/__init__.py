"""Chunked WKV6 (RWKV-6 "Finch") Pallas TPU kernel."""
from . import kernel, ops, ref  # noqa: F401
