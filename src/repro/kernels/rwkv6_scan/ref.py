"""Pure-jnp oracle: sequential per-token WKV6 recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, log_w, u, S0=None):
    """r,k,v,log_w: (B, H, T, N); u: (H, N).
    Returns (o (B,H,T,N_v), S_final (B,H,N,N))."""
    b, h, t, n = r.shape
    r32, k32, v32 = (x.astype(jnp.float32) for x in (r, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))
    if S0 is None:
        S0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                      # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        ot = jnp.einsum("bhn,bhnm->bhm", rt,
                        S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, ot

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (r32, k32, v32, w))
    S_fin, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 2, 0, 3).astype(r.dtype), S_fin
