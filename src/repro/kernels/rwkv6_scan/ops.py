"""Public wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import wkv6_pallas

__all__ = ["wkv6"]


def wkv6(r, k, v, log_w, u, *, chunk: int = 16, interpret: bool = False):
    """r,k,v,log_w: (B, H, T, N); u: (H, N).  Pads T to the chunk size;
    padded tokens use log_w = 0 (decay 1) and k = 0 so the state is inert."""
    b, h, t, n = r.shape
    pt = -t % chunk
    if pt:
        pad4 = ((0, 0), (0, 0), (0, pt), (0, 0))
        r = jnp.pad(r, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        log_w = jnp.pad(log_w, pad4)
    out = wkv6_pallas(r, k, v, log_w, u, chunk=chunk, interpret=interpret)
    return out[:, :, :t]
