"""Public wrapper: pad (B, S, W) to tile multiples and scan."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import lru_scan_pallas

__all__ = ["lru_scan"]


def lru_scan(a, b, *, interpret: bool = False,
             block_b: int = 8, block_t: int = 128, block_w: int = 128):
    bsz, s, w = a.shape
    pb, pt, pw = -bsz % block_b, -s % block_t, -w % block_w
    if pb or pt or pw:
        pad = ((0, pb), (0, pt), (0, pw))
        a = jnp.pad(a, pad)   # a=0 on padding keeps the recurrence inert
        b = jnp.pad(b, pad)
    h = lru_scan_pallas(a, b, block_b=block_b, block_t=block_t,
                        block_w=block_w, interpret=interpret)
    return h[:bsz, :s, :w]
