"""RG-LRU linear-recurrence scan Pallas TPU kernel (RecurrentGemma)."""
from . import kernel, ops, ref  # noqa: F401
