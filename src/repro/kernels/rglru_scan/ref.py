"""Pure-jnp oracle for the gated linear recurrence h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan(a, b, h0=None):
    """a, b: (B, S, W); h0: (B, W) or None.  Returns (h (B,S,W), h_last)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]
