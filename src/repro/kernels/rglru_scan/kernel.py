"""Pallas TPU kernel: blocked gated linear recurrence (RG-LRU).

TPU adaptation: the recurrence h_t = a_t * h_{t-1} + b_t is elementwise per
channel, so the natural TPU layout keeps a (Bb, Bw) tile of (batch, channel)
lanes resident in VMEM and walks time sequentially *inside* the kernel while
the grid walks time *blocks* (innermost) -- state persists in VMEM scratch
between time blocks, so HBM sees each element exactly once in and once out.
Channels are 128-lane aligned; batch rows 8-sublane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(i, h):
        a = a_ref[:, i, :].astype(jnp.float32)
        b = b_ref[:, i, :].astype(jnp.float32)
        h = a * h + b
        o_ref[:, i, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_b", "block_t", "block_w",
                                             "interpret"))
def lru_scan_pallas(a, b, *, block_b: int = 8, block_t: int = 128,
                    block_w: int = 128, interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W).  B % Bb == S % Bt == W % Bw == 0."""
    bsz, s, w = a.shape
    assert bsz % block_b == 0 and s % block_t == 0 and w % block_w == 0
    grid = (bsz // block_b, w // block_w, s // block_t)  # time innermost
    return pl.pallas_call(
        functools.partial(_lru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t, block_w),
                               lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b)
