"""Pure-jnp oracle for the pairwise-distance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pairwise_distance"]


@jax.jit
def pairwise_distance(points: jax.Array) -> jax.Array:
    """D[i, j] = ||x_i - x_j||_2 for points (N, F) -> (N, N)."""
    x = jnp.asarray(points, jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    gram = x @ x.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.sqrt(jnp.maximum(d2, 0.0))
