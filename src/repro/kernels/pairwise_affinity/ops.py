"""Public jitted wrapper: pad to MXU tiles, run the Pallas kernel, slice."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import pairwise_distance_pallas

__all__ = ["pairwise_distance"]


def pairwise_distance(points, *, block: int = 128, interpret: bool = False):
    """Pairwise Euclidean distances via the Pallas TPU kernel.

    ``interpret=True`` executes the kernel body in Python on CPU (used for
    validation in this repo; on TPU hardware leave it False).
    """
    x = jnp.asarray(points, jnp.float32)
    n, f = x.shape
    n_pad = -n % block
    f_pad = -f % 128  # lane alignment for the MXU contraction
    xp = jnp.pad(x, ((0, n_pad), (0, f_pad)))
    out = pairwise_distance_pallas(xp, block_m=block, block_n=block,
                                   interpret=interpret)
    return out[:n, :n]
