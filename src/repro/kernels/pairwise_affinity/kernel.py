"""Pallas TPU kernel: tiled pairwise Euclidean distances.

TPU-native design (not a CUDA port): the (N, N) distance matrix is produced
in 128x128 MXU-aligned tiles.  Each grid cell loads a (Bm, F) row block and a
(Bn, F) column block into VMEM, computes the Gram tile on the MXU via
``jnp.dot(..., preferred_element_type=f32)`` and finishes on the VPU with the
||x||^2 + ||y||^2 - 2<x,y> expansion.  F (feature dim, ~10) is zero-padded to
the 128-lane boundary by the wrapper so every matmul operand is
hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_distance_kernel", "pairwise_distance_pallas"]


def pairwise_distance_kernel(x_ref, y_ref, out_ref):
    """One (Bm, Bn) output tile: distances between x rows and y rows."""
    x = x_ref[...].astype(jnp.float32)           # (Bm, F)
    y = y_ref[...].astype(jnp.float32)           # (Bn, F)
    gram = jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (Bm, Bn) on the MXU
    xsq = jnp.sum(x * x, axis=1, keepdims=True)  # (Bm, 1)
    ysq = jnp.sum(y * y, axis=1, keepdims=True)  # (Bn, 1)
    d2 = xsq + ysq.T - 2.0 * gram
    out_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def pairwise_distance_pallas(points: jax.Array, *, block_m: int = 128,
                             block_n: int = 128,
                             interpret: bool = False) -> jax.Array:
    """(N_pad, F_pad) -> (N_pad, N_pad); caller pads/slices.

    Grid is (N/Bm, N/Bn); both operands stream the full (padded) feature dim
    so each tile is a single VMEM-resident MXU contraction:
    VMEM footprint = Bm*F + Bn*F + Bm*Bn floats ~= 194 KiB at 128/128/128.
    """
    n, f = points.shape
    assert n % block_m == 0 and n % block_n == 0, "pad N to the block size"
    grid = (n // block_m, n // block_n)
    return pl.pallas_call(
        pairwise_distance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(points, points)
