"""Pairwise Euclidean-distance (cluster affinity) kernel.

The O(N^2) affinity matrix of CRCH's clustering module (paper Eq. 5) is the
scheduler's compute hot spot.  ``kernel.py`` holds the Pallas TPU kernel,
``ops.py`` the jitted public wrapper, ``ref.py`` the pure-jnp oracle.
"""
from . import kernel, ops, ref  # noqa: F401
