"""Public wrapper: pad sequences to MXU blocks, call the kernel, slice."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D).  Pads Sq/Sk to block
    multiples (padded keys are masked out by the causal/softmax path:
    padded K rows produce NEG_INF scores via position masking only under
    ``causal``; for bidirectional use, pass pre-padded inputs)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    pq = -sq % block_q
    pk = -sk % block_k
    if pq or pk:
        if not causal:
            raise ValueError("non-causal inputs must be pre-padded")
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out[:, :, :sq]
