"""Pallas TPU flash attention (forward), GQA + causal.

TPU-native design: grid (B, H, Sq/Bq, Sk/Bk) with the KV index innermost so
the online-softmax running statistics (m, l) and the output accumulator
persist in VMEM scratch across KV steps of one query block.  Every matmul is
MXU-shaped ((Bq, D) x (D, Bk) and (Bq, Bk) x (Bk, D) with D, Bq, Bk multiples
of 128); masking/rescaling runs on the VPU in fp32.  GQA is expressed purely
through the BlockSpec index maps (query head h reads KV head h // group), so
no repeated-KV materialization ever exists in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (Bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        s.shape, 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                            # (Bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (Bq, Bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D); Sq % Bq == Sk % Bk == 0."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    assert h % kv == 0 and sq % block_q == 0 and sk % block_k == 0
    group = h // kv
    n_q, n_k = sq // block_q, sk // block_k
    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), block_q=block_q,
        block_k=block_k, causal=causal, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
