"""Pure-jnp oracle: GQA scaled-dot-product attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D) with H % KV == 0."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, d).astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)
