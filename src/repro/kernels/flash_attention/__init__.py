"""Flash attention (GQA, causal/full) Pallas TPU kernel."""
from . import kernel, ops, ref  # noqa: F401
