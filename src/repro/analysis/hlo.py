"""Post-SPMD HLO analysis: per-device collective bytes with loop scaling.

``compiled.as_text()`` prints each computation once; ``lax.scan`` lowers to a
``while`` whose body executes trip-count times.  A flat grep therefore
under-counts collectives inside the layer stack by ~L x.  This module parses
the HLO into computations, finds ``while`` ops, extracts the trip count from
the loop-condition's comparison constant, and recursively scales nested
collective bytes (layer scan inside grad-accumulation scan, etc.).

Byte convention: the *result shape* of the op is recorded (per-device, since
post-SPMD shapes are per-partition).  The roofline converts these to link
traffic with the standard ring factors:
  all-reduce ~ 2x, all-gather / reduce-scatter ~ 1x (times (n-1)/n ~ 1),
  all-to-all ~ 1x, collective-permute ~ 1x.
"""
from __future__ import annotations

import dataclasses
import gzip
import re

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalize_cost_analysis(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jaxlib versions.

    Older jaxlibs return a single properties dict; newer ones return a
    one-element list of dicts (one per partition).  Always returns a plain
    ``dict`` (empty when the analysis is unavailable), so callers can index
    ``["flops"]`` etc. without version guards.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,?\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_OP_RE = re.compile(r"=\s+(\S.*?)\s+([a-z0-9\-]+)\(")


def _comp_header(raw: str) -> tuple[str | None, bool]:
    """(computation name, is_entry) if this line opens a computation."""
    if raw[:1] in (" ", "\t") or "{" not in raw:
        return None, False
    m = _HEADER_RE.match(raw)
    if not m:
        return None, False
    return m.group(2), bool(m.group(1))


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    coll_bytes: dict
    coll_counts: dict
    whiles: list          # (condition_name, body_name)
    coll_bytes_f32: dict = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})


def _f32_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt != "f32":
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * 4
    return total


def parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        name, is_entry = _comp_header(raw)
        if name is not None:
            cur = Computation(name, is_entry,
                              {c: 0 for c in COLLECTIVES},
                              {c: 0 for c in COLLECTIVES}, [])
            comps[name] = cur
            if is_entry:
                entry_name = name
            continue
        if cur is None:
            continue
        wm = _WHILE_RE.search(line)
        if wm:
            cur.whiles.append((wm.group(1), wm.group(2)))
        om = _OP_RE.search(line)
        if om:
            type_str, op = om.group(1), om.group(2)
            for c in COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    if op.endswith("-done"):
                        break  # counted at -start
                    cur.coll_bytes[c] += _shape_bytes(type_str)
                    cur.coll_bytes_f32[c] += _f32_bytes(type_str)
                    cur.coll_counts[c] += 1
                    break
    return comps, entry_name


def _trip_count(cond_text: list[str]) -> int:
    """Max integer constant in the loop condition (induction bound)."""
    best = 1
    for line in cond_text:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def collective_totals(text: str) -> dict:
    """Trip-count-scaled per-device collective bytes/counts per op kind."""
    # gather raw text per computation for trip-count extraction
    comp_lines: dict[str, list[str]] = {}
    cur_name = None
    for raw in text.splitlines():
        name, _ = _comp_header(raw)
        if name is not None:
            cur_name = name
            comp_lines[cur_name] = []
            continue
        if cur_name is not None:
            comp_lines[cur_name].append(raw)

    comps, entry = parse_computations(text)
    memo: dict[str, tuple[dict, dict, dict]] = {}

    def total(name: str, depth=0) -> tuple[dict, dict, dict]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 16:
            z = {c: 0 for c in COLLECTIVES}
            return z, dict(z), dict(z)
        b = dict(comp.coll_bytes)
        n = dict(comp.coll_counts)
        f = dict(comp.coll_bytes_f32)
        for cond, body in comp.whiles:
            trips = _trip_count(comp_lines.get(cond, []))
            bb, bn, bf = total(body, depth + 1)
            for c in COLLECTIVES:
                b[c] += trips * bb[c]
                n[c] += trips * bn[c]
                f[c] += trips * bf[c]
        memo[name] = (b, n, f)
        return b, n, f

    if entry is None:
        # fall back: flat sum
        b = {c: 0 for c in COLLECTIVES}
        n = {c: 0 for c in COLLECTIVES}
        f = {c: 0 for c in COLLECTIVES}
        for comp in comps.values():
            for c in COLLECTIVES:
                b[c] += comp.coll_bytes[c]
                n[c] += comp.coll_counts[c]
                f[c] += comp.coll_bytes_f32[c]
        return {"bytes": b, "counts": n, "bytes_f32": f, "scaled": False}
    b, n, f = total(entry)
    return {"bytes": b, "counts": n, "bytes_f32": f, "scaled": True}


def load_hlo(path: str) -> str:
    if path.endswith(".gz"):
        with gzip.open(path, "rt") as f:
            return f.read()
    with open(path) as f:
        return f.read()


# effective link-bytes multipliers (ring algorithms)
LINK_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def link_bytes(totals: dict) -> float:
    return sum(LINK_FACTOR[c] * totals["bytes"][c] for c in COLLECTIVES)
