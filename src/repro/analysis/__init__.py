from . import flops, hlo  # noqa: F401
