"""Analytic FLOP / HBM-byte accounting per (architecture x shape) cell.

Why analytic: XLA's ``compiled.cost_analysis()`` counts while-loop *bodies
once* (verified in tests/test_analysis.py), and every layer stack here is a
``lax.scan`` -- so raw HLO numbers under-count by ~L x.  We therefore compute
FLOPs/bytes from the model definition and *validate the formulas against an
unrolled tiny config's cost_analysis* (same test file).

Conventions: 1 MAC = 2 FLOPs.  ``TRAIN_MULT`` = 1 fwd + 2 bwd + 1 remat
recompute of the scanned blocks.  Capacity-factor MoE counts dispatched
slots (dropped tokens still occupy capacity).  Attention pair counts: causal
S^2/2, local-window S*w - w^2/2, bidirectional S_q*S_k.
"""
from __future__ import annotations

import dataclasses
import math

from repro.models.config import ModelConfig
from repro.models.layers import MOE_GROUP
from repro.launch.shapes import Shape

TRAIN_MULT_MATMUL = 4.0   # fwd + bwd(2x) + remat fwd recompute
FWD_ONLY = 1.0


@dataclasses.dataclass
class CellCost:
    flops: float              # total FLOPs per step (global, all devices)
    model_flops: float        # 6*N*D (dense) / 6*N_active*D (MoE) for train,
                              # 2*N*D for inference shapes
    hbm_bytes: float          # global HBM traffic per step (see notes)
    notes: dict


def _attn_pairs(kind: str, s_q: int, s_k: int, window: int = 0) -> float:
    if kind == "causal":
        return s_q * s_q / 2.0
    if kind == "local":
        w = min(window, s_q)
        return s_q * w - w * w / 2.0
    return float(s_q) * s_k     # bidir / cross


def _layer_matmul_params(cfg: ModelConfig) -> dict:
    """Per-layer weight-matmul parameter counts by kind."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    out = {}
    out["attn_proj"] = d * nq + 2 * d * nkv + nq * d
    out["mlp"] = (2 if cfg.mlp_type == "gelu" else 3) * d * ff
    if cfg.is_moe:
        out["router"] = d * cfg.n_experts
    if cfg.rwkv:
        out["attn_proj"] = 0
        out["tm"] = 5 * d * d + d * (32 * 5) * 2 + d * 32 * 2
        out["mlp"] = 2 * d * ff + d * d
    return out


def _moe_group(cfg: ModelConfig, b: int, s: int) -> int:
    """Mirror of moe_forward's grouping."""
    if s >= MOE_GROUP and s % MOE_GROUP == 0:
        return MOE_GROUP
    if s == 1:
        return b
    return s


def _moe_expert_flops(cfg: ModelConfig, tokens: float, group: int,
                      mult: float) -> float:
    """Expert FFN + grouped dispatch/combine einsum FLOPs."""
    d, ff = cfg.d_model, cfg.d_ff
    cap = max(4, math.ceil(group * cfg.top_k * cfg.capacity_factor
                           / cfg.n_experts))
    slots = (tokens / group) * cfg.n_experts * cap
    expert = 2 * slots * 3 * d * ff
    # dispatch 'bgd,bgec->becd' + combine: E*C*D*G MACs per group each
    dispatch = 2 * 2 * tokens * cfg.n_experts * cap * d
    return (expert + dispatch) * mult


def _rglru_layout(cfg: ModelConfig):
    span = cfg.rec_per_attn + 1
    n_attn = cfg.n_layers // span
    n_rec = cfg.n_layers - n_attn
    return n_rec, n_attn


def cell_flops(cfg: ModelConfig, shape: Shape) -> CellCost:
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    ctx = shape.seq_len                      # kv length for decode
    if cfg.n_image_tokens and shape.kind == "train":
        s = shape.seq_len                    # image+text total stays seq_len
    tokens = float(b) * s
    mult = TRAIN_MULT_MATMUL if shape.kind == "train" else FWD_ONLY
    hd = cfg.head_dim
    d = cfg.d_model
    lm = _layer_matmul_params(cfg)
    notes = {}

    total = 0.0
    # ---- per-layer projections + mixers -----------------------------------
    if cfg.rwkv:
        per_layer = 2 * tokens * (lm["tm"] + lm["mlp"])
        # WKV6 state math: per token per head: 2*N*N MAC-ish terms (o and S)
        h = d // 64
        state = tokens * h * (4 * 64 * 64)
        total += cfg.n_layers * (per_layer + 2 * state) * mult
    elif cfg.rglru:
        n_rec, n_attn = _rglru_layout(cfg)
        w = cfg.lru_width
        rec_proj = 2 * tokens * (2 * d * w + 2 * w * w + w * d + lm["mlp"])
        rec_state = tokens * w * 12            # gates, scan combine, conv
        attn_proj = 2 * tokens * (lm["attn_proj"] + lm["mlp"])
        if shape.kind == "decode":
            pairs = float(min(cfg.window, ctx)) * b
        else:
            pairs = b * _attn_pairs("local", s, s, cfg.window)
        attn_mix = 4 * pairs * cfg.n_heads * hd
        total += (n_rec * (rec_proj + rec_state)
                  + n_attn * (attn_proj + attn_mix)) * mult
    else:
        per_layer = 2 * tokens * (lm["attn_proj"]
                                  + (0 if cfg.is_moe else lm["mlp"]))
        if shape.kind == "decode":
            pairs = float(ctx) * b
        else:
            pairs = b * _attn_pairs("causal", s, s)
        attn_mix = 4 * pairs * cfg.n_heads * hd
        total += cfg.n_layers * (per_layer + attn_mix) * mult
        if cfg.is_moe:
            group = _moe_group(cfg, b, s)
            total += cfg.n_layers * (
                _moe_expert_flops(cfg, tokens, group, mult)
                + 2 * tokens * lm["router"] * mult)
        if cfg.is_encdec:
            enc_tokens = float(b) * cfg.n_frames
            enc = cfg.encoder_layers * (
                2 * enc_tokens * (lm["attn_proj"] + lm["mlp"])
                + 4 * b * _attn_pairs("bidir", cfg.n_frames, cfg.n_frames)
                * cfg.n_heads * hd)
            # encoder runs once; with remat on train it recomputes once
            total += enc * (2.0 if shape.kind == "train" else 1.0)
            cross_proj = 2 * (tokens + enc_tokens) * (d * cfg.n_heads * hd)
            cross_pairs = b * _attn_pairs("bidir", s, cfg.n_frames) \
                if shape.kind != "decode" else b * float(cfg.n_frames)
            total += cfg.n_layers * (cross_proj * 2
                                     + 4 * cross_pairs * cfg.n_heads * hd) \
                * mult
    # ---- lm head / embedding ----------------------------------------------
    head_tokens = tokens if shape.kind == "train" else float(b)
    total += 2 * head_tokens * d * cfg.vocab_size * \
        (3.0 if shape.kind == "train" else 1.0)  # xent fwd+bwd, no remat

    # ---- MODEL_FLOPS -------------------------------------------------------
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * (tokens if shape.kind == "prefill"
                                        else float(b))

    # ---- HBM bytes (global, per step) --------------------------------------
    p_total = cfg.param_count()
    if shape.kind == "train":
        act_bytes = _activation_bytes(cfg, b, s)
        # params: fwd read + bwd read + grad write/read + opt 6x fp32
        hbm = p_total * 4 * (2 + 2 + 6) + act_bytes
    elif shape.kind == "prefill":
        hbm = p_total * 4 + _activation_bytes(cfg, b, s) / 2
    else:
        hbm = n_active * 4 + _cache_bytes(cfg, b, ctx)
    return CellCost(flops=total, model_flops=model_flops, hbm_bytes=hbm,
                    notes=notes)


def _activation_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    """Stored remat boundaries: one (B,S,D) bf16 per scanned block, written
    once + read once during backward."""
    per_layer = 2.0 * b * s * cfg.d_model * 2
    return cfg.n_layers * per_layer * 2


def _cache_bytes(cfg: ModelConfig, b: int, ctx: int) -> float:
    if cfg.rwkv:
        h = cfg.d_model // 64
        return cfg.n_layers * (b * h * 64 * 64 * 4 + 2 * b * cfg.d_model * 2)
    if cfg.rglru:
        n_rec, n_attn = _rglru_layout(cfg)
        kv = 2 * b * min(cfg.window, ctx) * cfg.n_kv_heads * cfg.head_dim * 2
        st = b * cfg.lru_width * (4 + 2 * (cfg.conv_width - 1))
        return n_attn * kv + n_rec * st
    kv = 2.0 * b * ctx * cfg.n_kv_heads * cfg.head_dim * 2
    total = cfg.n_layers * kv
    if cfg.is_encdec:
        total += cfg.n_layers * 2.0 * b * cfg.n_frames * \
            cfg.n_kv_heads * cfg.head_dim * 2
    return total
